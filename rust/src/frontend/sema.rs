//! Semantic analysis over the parsed AST: the `lmtuner lint` engine and
//! the staging-safety certifier the future source-to-source transform
//! depends on (ROADMAP item 3).
//!
//! One symbolic walk per kernel drives every rule. The walk reuses the
//! extractor's binding machinery (affine forms over work-item intrinsics
//! and counted loops, [`super::extract::trip_count`],
//! [`super::extract::LoopCtx`]) but runs on a *divergence lattice*
//! instead of the extractor's hard-error value domain:
//!
//! ```text
//!   Aff(affine)  — known affine form; lane-variant iff it has a
//!                  gid/lid term
//!   Uniform      — value unknown, but identical across the work-items
//!                  of a group (scalar arguments, loads at uniform
//!                  indices, loop counters with uniform bounds)
//!   Variant      — may differ between work-items (lane-variant)
//! ```
//!
//! Where the extractor refuses (unbound `--set`, non-affine index), the
//! linter degrades: the value drops to `Uniform`/`Variant` and the
//! affine-interval checks for the affected access are skipped — barrier
//! divergence is still checked, because kernel arguments are uniform by
//! definition. Rules (IDs and severities in [`super::diag::Rule`],
//! contract in DESIGN.md §2h):
//!
//! * **LM001 barrier divergence (Deny)** — `barrier()` reachable under a
//!   lane-variant branch, inside a loop whose bounds are lane-variant,
//!   or after a lane-variant guarded `return`.
//! * **LM002 affine bounds (Deny)** — the tap/constant column offsets of
//!   a 2D access reach a full row stride, so the flattened index wraps
//!   into a different row; no host-side apron allocation can make that
//!   access mean what it says. (Sub-stride apron reads at the grid
//!   border are the host's documented responsibility, exactly the
//!   paper's staging-region assumption.)
//! * **LM003 region budget (Warn)** — the staged region for an array
//!   exceeds [`crate::gpu::spec::DeviceSpec::lmem_budget_per_wg`];
//!   reported through the staging certificate.
//! * **LM004 bank conflict (Warn)** — the x-lane element stride of a
//!   column coordinate is a nonzero multiple of the 32 banks while the
//!   row does not depend on x: were the array staged as-is, all warp
//!   lanes would hit one bank, and the extractor's +1-column pad (which
//!   only fires for transposed accesses) would not apply.
//! * **LM005 uncoalesced access (Warn in a loop, Note otherwise)** —
//!   more than one DRAM transaction per warp access. One-off accesses
//!   demote to Note: a transpose-shaped epilogue store is precisely what
//!   the staging transform exists to fix, not a defect of the input.
//!   Suppressed where LM004 already fired on the same access.
//! * **LM006 staging certificate (Note)** — `stageable: yes/no` plus
//!   reasons per accessed `__global` array (see [`certify`]).

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use super::access::{split_row_col, tx_per_access, Affine, Var};
use super::ast::{AddrSpace, AssignOp, BinOp, Expr, ForStep, Kernel, Program, Stmt};
use super::diag::{Diagnostics, Rule, Severity};
use super::extract::{
    self, assigned_scalars, is_int_type, trip_count, AnalyzeOptions, Bindings, ExtractError,
    ExtractErrorKind, LoopCtx, MAX_TRIP,
};
use super::lexer::Pos;
use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::launch::Launch;
use crate::util::json::Json;

/// Shared-memory banks on every Fermi/Kepler part in the registry.
const BANKS: i64 = 32;

/// What to lint: which kernel(s), the launch geometry, scalar bindings,
/// and whether to attempt a staging certificate per accessed array.
#[derive(Clone, Debug)]
pub struct SemaOptions {
    /// Kernel name; `None` lints every kernel in the file.
    pub kernel: Option<String>,
    pub launch: Launch,
    pub bindings: Bindings,
    /// Certify each accessed `__global` array (the `lint` path). The
    /// `analyze` gate runs with this off and certifies its target
    /// separately.
    pub certificates: bool,
}

/// Why an array failed the staging-safety certificate.
#[derive(Clone, Debug)]
pub enum CertReason {
    /// The extractor's affine analysis failed (non-affine index, unbound
    /// scalar, unsupported construct ...): the full positioned message.
    Analysis(String),
    /// The array has both load and store sites: staging the region with
    /// no barrier between the aliasing accesses is unsafe.
    MixedReadWrite { loads: u32, stores: u32 },
    /// The staged region does not fit the device budget.
    OverBudget { need: u64, budget: u64 },
}

impl fmt::Display for CertReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CertReason::Analysis(msg) => write!(f, "{msg}"),
            CertReason::MixedReadWrite { loads, stores } => write!(
                f,
                "{loads} load and {stores} store site(s) alias the staged region \
                 between barriers"
            ),
            CertReason::OverBudget { need, budget } => {
                write!(f, "staged region needs {need} B but the device budget is {budget} B")
            }
        }
    }
}

/// The staging-safety certificate for one (kernel, array) pair: the
/// conditions the source-to-source `__local` transform needs, proven or
/// refuted with reasons.
#[derive(Clone, Debug)]
pub struct StagingCertificate {
    pub kernel: String,
    pub array: String,
    pub stageable: bool,
    /// Empty iff `stageable`.
    pub reasons: Vec<CertReason>,
    /// Staged-region footprint; `None` when affine analysis failed.
    pub region_bytes: Option<u64>,
    /// The device's per-workgroup local-memory budget the region was
    /// checked against.
    pub budget_bytes: u64,
}

impl StagingCertificate {
    /// One-line human rendering (`analyze` prints this beside the forest
    /// verdict).
    pub fn summary(&self) -> String {
        if self.stageable {
            format!(
                "stageable: yes (region {} B within the {} B budget)",
                self.region_bytes.unwrap_or(0),
                self.budget_bytes
            )
        } else {
            let reasons: Vec<String> = self.reasons.iter().map(|r| r.to_string()).collect();
            format!("stageable: no ({})", reasons.join("; "))
        }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kernel", Json::Str(self.kernel.clone()))
            .set("array", Json::Str(self.array.clone()))
            .set("stageable", Json::Bool(self.stageable))
            .set(
                "region_bytes",
                match self.region_bytes {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                },
            )
            .set("budget_bytes", Json::Num(self.budget_bytes as f64))
            .set(
                "reasons",
                Json::Arr(self.reasons.iter().map(|r| Json::Str(r.to_string())).collect()),
            );
        j
    }
}

/// Prove (or refute, with reasons) that staging `opts.target` is legal:
/// affine indices only, no aliasing writes to the staged region between
/// barriers, region within the device's local-memory budget.
pub fn certify(prog: &Program, opts: &AnalyzeOptions, dev: &DeviceSpec) -> StagingCertificate {
    let budget = dev.lmem_budget_per_wg() as u64;
    match extract::extract_profile(prog, opts, dev) {
        Err(e) => StagingCertificate {
            kernel: opts.kernel.clone().unwrap_or_default(),
            array: opts.target.clone(),
            stageable: false,
            reasons: vec![CertReason::Analysis(e.to_string())],
            region_bytes: None,
            budget_bytes: budget,
        },
        Ok(p) => {
            let mut reasons = Vec::new();
            if p.target_loads > 0 && p.target_stores > 0 {
                reasons.push(CertReason::MixedReadWrite {
                    loads: p.target_loads,
                    stores: p.target_stores,
                });
            }
            let need = p.descriptor.region_bytes();
            if need > budget {
                reasons.push(CertReason::OverBudget { need, budget });
            }
            StagingCertificate {
                kernel: p.descriptor.name.clone(),
                array: opts.target.clone(),
                stageable: reasons.is_empty(),
                reasons,
                region_bytes: Some(need),
                budget_bytes: budget,
            }
        }
    }
}

/// Everything one lint run produced: the diagnostics stream (which
/// includes LM006 certificate notes) plus the structured certificates.
#[derive(Debug)]
pub struct LintReport {
    pub diags: Diagnostics,
    pub certificates: Vec<StagingCertificate>,
}

impl LintReport {
    /// The `lint --json` document: file, severity summary, diagnostics,
    /// and structured certificates — round-trips through [`Json::parse`].
    pub fn to_json(&self, file: &str) -> Json {
        let mut j = self.diags.to_json();
        j.set("file", Json::Str(file.to_string())).set(
            "certificates",
            Json::Arr(self.certificates.iter().map(StagingCertificate::to_json).collect()),
        );
        j
    }
}

/// Lint every selected kernel in `prog`. The only hard errors are the
/// selection ones the extractor would also raise (no kernels, unknown
/// `--kernel`); everything about the kernel *bodies* comes back as
/// diagnostics, never as an `Err`.
pub fn lint_program(
    prog: &Program,
    opts: &SemaOptions,
    dev: &DeviceSpec,
) -> Result<LintReport, ExtractError> {
    if prog.kernels.is_empty() {
        return Err(ExtractError { pos: Pos::start(), kind: ExtractErrorKind::NoKernels });
    }
    let kernels: Vec<&Kernel> = match &opts.kernel {
        Some(want) => {
            let k = prog.kernels.iter().find(|k| &k.name == want).ok_or(ExtractError {
                pos: Pos::start(),
                kind: ExtractErrorKind::UnknownKernel {
                    name: want.clone(),
                    available: prog.kernels.iter().map(|k| k.name.clone()).collect(),
                },
            })?;
            vec![k]
        }
        None => prog.kernels.iter().collect(),
    };
    let mut diags = Diagnostics::new();
    let mut certificates = Vec::new();
    for k in kernels {
        check_kernel(prog, k, opts, dev, &mut diags, &mut certificates);
    }
    diags.sort();
    Ok(LintReport { diags, certificates })
}

// ---------------------------------------------------------------------
// The divergence-lattice walk.

/// Abstract value: affine, uniform-but-unknown, or lane-variant.
#[derive(Clone, Debug)]
enum SVal {
    Aff(Affine),
    Uniform,
    Variant,
}

impl SVal {
    /// May this value differ between work-items of one group?
    fn is_variant(&self) -> bool {
        match self {
            SVal::Aff(a) => a.depends_on_wi(),
            SVal::Uniform => false,
            SVal::Variant => true,
        }
    }

    fn as_const(&self) -> Option<i64> {
        match self {
            SVal::Aff(a) => a.as_const(),
            _ => None,
        }
    }

    /// Lattice join of two non-affine values.
    fn join(a: &SVal, b: &SVal) -> SVal {
        if a.is_variant() || b.is_variant() {
            SVal::Variant
        } else {
            SVal::Uniform
        }
    }
}

/// One recorded array access; `index: None` when the subscript did not
/// reduce to an affine form (interval checks are skipped for it).
struct SiteRec {
    array: String,
    space: AddrSpace,
    index: Option<Affine>,
    in_loop: bool,
    is_store: bool,
    pos: Pos,
}

struct Checker<'a> {
    kernel: String,
    env: BTreeMap<String, SVal>,
    arrays: BTreeMap<String, AddrSpace>,
    launch: Launch,
    /// Resolved contexts for counted loops; `Var::Loop(i)` indexes this.
    /// `None`: the loop exists but its range is unknown.
    loops: Vec<Option<LoopCtx>>,
    loop_depth: usize,
    /// Positions of the lane-variant branches/loops currently open.
    div_stack: Vec<Pos>,
    /// A lane-variant guarded `return` has been passed: every later
    /// barrier is divergent regardless of local control flow.
    divergent_exit: bool,
    sites: Vec<SiteRec>,
    diags: &'a mut Diagnostics,
}

fn check_kernel(
    prog: &Program,
    k: &Kernel,
    opts: &SemaOptions,
    dev: &DeviceSpec,
    diags: &mut Diagnostics,
    certificates: &mut Vec<StagingCertificate>,
) {
    let mut c = Checker {
        kernel: k.name.clone(),
        env: BTreeMap::new(),
        arrays: BTreeMap::new(),
        launch: opts.launch,
        loops: Vec::new(),
        loop_depth: 0,
        div_stack: Vec::new(),
        divergent_exit: false,
        sites: Vec::new(),
        diags,
    };
    let mut array_pos: BTreeMap<String, Pos> = BTreeMap::new();
    for p in &k.params {
        if p.is_ptr {
            c.arrays.insert(p.name.clone(), p.space);
            array_pos.insert(p.name.clone(), p.pos);
        } else {
            // Scalar kernel arguments are uniform across the NDRange by
            // definition — bound ones additionally carry their value.
            let v = match opts.bindings.get(&p.name) {
                Some(v) if is_int_type(&p.ty) => SVal::Aff(Affine::constant(v)),
                _ => SVal::Uniform,
            };
            c.env.insert(p.name.clone(), v);
        }
    }
    c.walk(&k.body);

    // Per-site interval / coalescing / bank rules.
    let sites = std::mem::take(&mut c.sites);
    for s in &sites {
        c.check_site(s, dev);
    }

    // Staging certificates for every accessed __global array.
    if opts.certificates {
        let accessed: BTreeSet<&String> = sites
            .iter()
            .filter(|s| s.space == AddrSpace::Global)
            .map(|s| &s.array)
            .collect();
        for name in accessed {
            let aopts = AnalyzeOptions {
                target: name.clone(),
                kernel: Some(k.name.clone()),
                launch: opts.launch,
                bindings: opts.bindings.clone(),
            };
            let cert = certify(prog, &aopts, dev);
            let pos = array_pos.get(name).copied().unwrap_or(k.pos);
            for r in &cert.reasons {
                if let CertReason::OverBudget { need, budget } = r {
                    c.diags.report(
                        Rule::RegionBudget,
                        pos,
                        &k.name,
                        Some(name),
                        format!(
                            "staging `{name}` needs a {need} B region; the {} \
                             local-memory budget is {budget} B",
                            dev.key
                        ),
                    );
                }
            }
            c.diags.report(
                Rule::Stageability,
                pos,
                &k.name,
                Some(name),
                format!("staging certificate for `{name}`: {}", cert.summary()),
            );
            certificates.push(cert);
        }
    }
}

impl<'a> Checker<'a> {
    fn walk(&mut self, body: &[Stmt]) {
        for s in body {
            self.walk_stmt(s);
        }
    }

    fn walk_stmt(&mut self, s: &Stmt) {
        match s {
            Stmt::Decl { name, init, .. } => {
                let v = match init {
                    Some(e) => self.eval(e),
                    None => SVal::Uniform,
                };
                self.env.insert(name.clone(), v);
            }
            Stmt::Assign { target, op, value, .. } => {
                let rhs = self.eval(value);
                match target {
                    Expr::Index { base, index, pos } => {
                        if let Expr::Var(array, _) = base.as_ref() {
                            let array = array.clone();
                            self.record_access(&array, index, true, *pos);
                        } else {
                            // Nested subscript targets are outside the
                            // subset; still walk for contained accesses.
                            self.eval(base);
                            self.eval(index);
                        }
                    }
                    Expr::Var(name, _) => {
                        let old = self.env.get(name).cloned().unwrap_or(SVal::Uniform);
                        let new = match op {
                            AssignOp::Set => rhs,
                            AssignOp::Add => self.combine(BinOp::Add, old, rhs),
                            AssignOp::Sub => self.combine(BinOp::Sub, old, rhs),
                            AssignOp::Mul => self.combine(BinOp::Mul, old, rhs),
                            AssignOp::Div => self.combine(BinOp::Div, old, rhs),
                        };
                        self.env.insert(name.clone(), new);
                    }
                    other => {
                        self.eval(other);
                    }
                }
            }
            Stmt::For { var, init, cond_op, bound, step, body, pos, .. } => {
                self.walk_for(var, init, *cond_op, bound, step, body, *pos);
            }
            Stmt::If { cond, then_body, else_body, pos } => {
                let divergent = self.eval(cond).is_variant();
                let mut assigned = BTreeSet::new();
                assigned_scalars(then_body, &mut assigned);
                assigned_scalars(else_body, &mut assigned);
                let saved = self.env.clone();
                if divergent {
                    self.div_stack.push(*pos);
                }
                self.walk(then_body);
                self.env = saved.clone();
                self.walk(else_body);
                self.env = saved;
                if divergent {
                    self.div_stack.pop();
                    if contains_return(then_body) || contains_return(else_body) {
                        self.divergent_exit = true;
                    }
                }
                // Values written under the branch: lane-variant when the
                // branch is, otherwise unknown-but-uniform (all lanes
                // took the same path).
                let merged = if divergent { SVal::Variant } else { SVal::Uniform };
                for n in &assigned {
                    if self.env.contains_key(n) {
                        self.env.insert(n.clone(), merged.clone());
                    }
                }
            }
            Stmt::Call { name, args, pos } => {
                if is_barrier(name) {
                    self.check_barrier(*pos);
                }
                for a in args {
                    self.eval(a);
                }
            }
            Stmt::Return { .. } => {}
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn walk_for(
        &mut self,
        var: &str,
        init: &Expr,
        cond_op: BinOp,
        bound: &Expr,
        step: &ForStep,
        body: &[Stmt],
        pos: Pos,
    ) {
        let vi = self.eval(init);
        let vb = self.eval(bound);
        let (step_variant, step_const) = match step {
            ForStep::Inc => (false, Some(1)),
            ForStep::Dec => (false, Some(-1)),
            ForStep::Add(e) => {
                let v = self.eval(e);
                (v.is_variant(), v.as_const())
            }
            ForStep::Sub(e) => {
                let v = self.eval(e);
                (v.is_variant(), v.as_const().and_then(i64::checked_neg))
            }
        };
        // A loop whose trip count depends on a lane-variant form makes
        // its whole body divergent.
        let divergent = vi.is_variant() || vb.is_variant() || step_variant;
        let ctx = match (vi.as_const(), vb.as_const(), step_const) {
            (Some(start), Some(b), Some(s)) if s != 0 => trip_count(start, b, s, cond_op)
                .filter(|&t| t > 0 && t <= MAX_TRIP)
                .map(|trip| LoopCtx { start, step: s, trip, depth: self.loop_depth }),
            _ => None,
        };
        let mut assigned = BTreeSet::new();
        assigned_scalars(body, &mut assigned);
        let saved = self.env.clone();
        // Accumulators are conservatively lane-variant inside and after
        // the loop (they usually fold lane-variant loads).
        self.mark(&assigned, SVal::Variant);
        let id = self.loops.len() as u32;
        let known = ctx.is_some();
        self.loops.push(ctx);
        let var_val = if known {
            SVal::Aff(Affine::var(Var::Loop(id)))
        } else if divergent {
            SVal::Variant
        } else {
            SVal::Uniform
        };
        self.env.insert(var.to_string(), var_val);
        if divergent {
            self.div_stack.push(pos);
        }
        self.loop_depth += 1;
        self.walk(body);
        self.loop_depth -= 1;
        if divergent {
            self.div_stack.pop();
        }
        self.env = saved;
        self.mark(&assigned, SVal::Variant);
    }

    fn mark(&mut self, names: &BTreeSet<String>, v: SVal) {
        for n in names {
            if self.env.contains_key(n) {
                self.env.insert(n.clone(), v.clone());
            }
        }
    }

    fn check_barrier(&mut self, pos: Pos) {
        if let Some(&branch) = self.div_stack.last() {
            self.diags.report(
                Rule::BarrierDivergence,
                pos,
                &self.kernel.clone(),
                None,
                format!(
                    "barrier() under work-item-divergent control flow (lane-variant \
                     branch or loop at {branch}): work-items of one group may not \
                     all reach it"
                ),
            );
        } else if self.divergent_exit {
            self.diags.report(
                Rule::BarrierDivergence,
                pos,
                &self.kernel.clone(),
                None,
                "barrier() after a work-item-divergent early return: exited \
                 work-items never reach it"
                    .to_string(),
            );
        }
    }

    fn eval(&mut self, e: &Expr) -> SVal {
        match e {
            Expr::Int(v, _) => SVal::Aff(Affine::constant(*v)),
            Expr::Float(..) => SVal::Uniform,
            Expr::Var(name, _) => self.env.get(name).cloned().unwrap_or(SVal::Uniform),
            Expr::Call { name, args, pos } => self.eval_call(name, args, *pos),
            Expr::Index { base, index, pos } => {
                if let Expr::Var(array, _) = base.as_ref() {
                    let array = array.clone();
                    self.record_access(&array, index, false, *pos)
                } else {
                    self.eval(base);
                    self.eval(index);
                    SVal::Variant
                }
            }
            Expr::Unary { op, expr, .. } => {
                let v = self.eval(expr);
                if *op == '-' {
                    if let SVal::Aff(a) = &v {
                        if let Ok(n) = a.neg() {
                            return SVal::Aff(n);
                        }
                    }
                }
                if v.is_variant() {
                    SVal::Variant
                } else {
                    SVal::Uniform
                }
            }
            Expr::Bin { op, lhs, rhs, .. } => {
                let l = self.eval(lhs);
                let r = self.eval(rhs);
                self.combine(*op, l, r)
            }
        }
    }

    /// Binary combination on the lattice: affine algebra where possible,
    /// variance join everywhere else (including comparisons — a compare
    /// of a lane-variant value is a lane-variant condition).
    fn combine(&mut self, op: BinOp, l: SVal, r: SVal) -> SVal {
        if op.is_arith() {
            if let (SVal::Aff(a), SVal::Aff(b)) = (&l, &r) {
                let out = match op {
                    BinOp::Add => a.add(b).ok(),
                    BinOp::Sub => a.sub(b).ok(),
                    BinOp::Mul => match (b.as_const(), a.as_const()) {
                        (Some(k), _) => a.scale(k).ok(),
                        (None, Some(k)) => b.scale(k).ok(),
                        _ => None,
                    },
                    BinOp::Div => match (a.as_const(), b.as_const()) {
                        (Some(x), Some(k)) if k != 0 => x.checked_div(k).map(Affine::constant),
                        (None, Some(k)) if k != 0 => a.div_exact(k),
                        _ => None,
                    },
                    BinOp::Rem => match (a.as_const(), b.as_const()) {
                        (Some(x), Some(k)) if k != 0 => x.checked_rem(k).map(Affine::constant),
                        _ => None,
                    },
                    _ => None,
                };
                if let Some(a) = out {
                    return SVal::Aff(a);
                }
            }
        }
        SVal::join(&l, &r)
    }

    fn eval_call(&mut self, name: &str, args: &[Expr], pos: Pos) -> SVal {
        if is_barrier(name) {
            self.check_barrier(pos);
            for a in args {
                self.eval(a);
            }
            return SVal::Uniform;
        }
        let dim = || -> Option<u8> {
            if args.len() != 1 {
                return None;
            }
            // Peeking the literal avoids recording accesses twice; dims
            // are always literal 0/1 in the supported subset.
            match &args[0] {
                Expr::Int(0, _) => Some(0),
                Expr::Int(1, _) => Some(1),
                _ => None,
            }
        };
        match name {
            "get_global_id" | "get_local_id" | "get_group_id" => match dim() {
                Some(d) => {
                    let v = match name {
                        "get_global_id" => Var::Gid(d),
                        "get_local_id" => Var::Lid(d),
                        _ => Var::Group(d),
                    };
                    SVal::Aff(Affine::var(v))
                }
                // Unsupported dimension: ids are lane-variant, group ids
                // are not.
                None => {
                    for a in args {
                        self.eval(a);
                    }
                    if name == "get_group_id" {
                        SVal::Uniform
                    } else {
                        SVal::Variant
                    }
                }
            },
            "get_local_size" | "get_global_size" | "get_num_groups" => match dim() {
                Some(d) => {
                    let l = self.launch;
                    let v = match (name, d) {
                        ("get_local_size", 0) => l.wg.w,
                        ("get_local_size", _) => l.wg.h,
                        ("get_global_size", 0) => l.grid.w,
                        ("get_global_size", _) => l.grid.h,
                        (_, 0) => l.groups_x(),
                        (_, _) => l.groups_y(),
                    };
                    SVal::Aff(Affine::constant(v as i64))
                }
                None => {
                    for a in args {
                        self.eval(a);
                    }
                    SVal::Uniform
                }
            },
            _ => {
                // Math builtins: walk the arguments (they may contain
                // accesses and barriers), variance joins over them.
                let mut v = SVal::Uniform;
                for a in args {
                    let av = self.eval(a);
                    v = SVal::join(&v, &av);
                }
                v
            }
        }
    }

    /// Record an array access; the value of a load is lane-variant iff
    /// its index is (same index ⇒ same loaded value on every lane).
    fn record_access(&mut self, array: &str, index: &Expr, is_store: bool, pos: Pos) -> SVal {
        let space = match self.arrays.get(array) {
            Some(s) => *s,
            None => {
                // Subscripting a scalar/unknown name: malformed, but the
                // extractor owns that error path; keep walking.
                self.eval(index);
                return SVal::Variant;
            }
        };
        let iv = self.eval(index);
        let lane = iv.is_variant();
        let aff = match iv {
            SVal::Aff(a) => Some(a),
            _ => None,
        };
        self.sites.push(SiteRec {
            array: array.to_string(),
            space,
            index: aff,
            in_loop: self.loop_depth > 0,
            is_store,
            pos,
        });
        if lane {
            SVal::Variant
        } else {
            SVal::Uniform
        }
    }

    // -----------------------------------------------------------------
    // Post-walk per-site rules.

    fn check_site(&mut self, s: &SiteRec, dev: &DeviceSpec) {
        let kernel = self.kernel.clone();
        let aff = match &s.index {
            Some(a) => a,
            None => return, // non-affine: the extractor's error path owns it
        };
        match s.space {
            AddrSpace::Local => {
                // Direct 32-bank model on the flat local index.
                let cx = aff.wi_coeff(0);
                if cx != 0 && cx % BANKS == 0 {
                    self.diags.report(
                        Rule::BankConflict,
                        s.pos,
                        &kernel,
                        Some(&s.array),
                        format!(
                            "`{}`: x-lane stride {cx} elements is a multiple of the \
                             {BANKS} shared-memory banks — all lanes hit one bank",
                            s.array
                        ),
                    );
                }
                return;
            }
            AddrSpace::Constant => return, // constant cache: no DRAM rules
            AddrSpace::Global | AddrSpace::Private => {}
        }
        let rc = match split_row_col(aff) {
            Ok(rc) => rc,
            Err(_) => return, // mixed stride: extractor's error path owns it
        };

        // LM002 — column offsets (constants + counted non-home loops)
        // must stay under one row stride; a full-stride offset wraps the
        // flattened index into a different row.
        if rc.stride > 0 {
            if let Some((lo, hi)) = self.col_offset_interval(&rc.col) {
                let stride = rc.stride as i128;
                if hi >= stride || lo <= -stride {
                    self.diags.report(
                        Rule::OutOfBounds,
                        s.pos,
                        &kernel,
                        Some(&s.array),
                        format!(
                            "`{}`: column offsets span {lo}..{hi} but the row stride \
                             is {} — the access wraps into a different row (no host \
                             apron can cover a full-stride offset)",
                            s.array, rc.stride
                        ),
                    );
                }
            }
        }

        // LM004 — predicted shared-memory bank conflict of the staged
        // tile: column walk with an x-lane stride that is a multiple of
        // the 32 banks. Transposed accesses (row depends on x) are
        // excluded: the extractor's +1-column pad already covers them.
        let cx = rc.col.wi_coeff(0);
        let bank_conflict = rc.row.wi_coeff(0) == 0 && cx != 0 && cx % BANKS == 0;
        if bank_conflict {
            self.diags.report(
                Rule::BankConflict,
                s.pos,
                &kernel,
                Some(&s.array),
                format!(
                    "`{}`: column walk with x-lane stride {cx} elements — a \
                     multiple of the {BANKS} banks, so a staged tile would \
                     serialize every warp access (the +1-column pad only \
                     applies to transposed accesses)",
                    s.array
                ),
            );
        }

        // LM005 — uncoalesced x-lane access. Suppressed when LM004
        // already diagnosed the same access (the bank conflict is the
        // more specific finding); demoted to Note outside loops.
        if !bank_conflict {
            let seg = (dev.transaction_bytes / 4).max(1);
            let tx = tx_per_access(&rc, &self.launch, dev.warp_size, seg);
            if tx > 1.0 {
                let (sev, tail) = if s.in_loop {
                    (Severity::Warn, "inside a loop")
                } else {
                    (Severity::Note, "a one-off access; staging is the usual fix")
                };
                self.diags.report_as(
                    Rule::Uncoalesced,
                    sev,
                    s.pos,
                    &kernel,
                    Some(&s.array),
                    format!(
                        "`{}`: {} at ~{tx:.0} DRAM transactions per warp ({tail})",
                        s.array,
                        if s.is_store { "uncoalesced store" } else { "uncoalesced load" }
                    ),
                );
            }
        }
    }

    /// Interval of a column coordinate's non-home terms: the constant
    /// plus every counted-loop term over its range. Work-item and group
    /// terms are the home position (excluded); an unknown loop range
    /// makes the interval unknown (`None`).
    fn col_offset_interval(&self, col: &Affine) -> Option<(i128, i128)> {
        let mut lo = col.c as i128;
        let mut hi = col.c as i128;
        for (v, c) in &col.terms {
            match v {
                Var::Gid(_) | Var::Lid(_) | Var::Group(_) => {}
                Var::Loop(i) => {
                    let ctx = self.loops.get(*i as usize)?.as_ref()?;
                    let (mn, mx) = ctx.value_range();
                    let d0 = (*c as i128) * mn;
                    let d1 = (*c as i128) * mx;
                    lo += d0.min(d1);
                    hi += d0.max(d1);
                }
            }
        }
        Some((lo, hi))
    }
}

fn is_barrier(name: &str) -> bool {
    matches!(name, "barrier" | "work_group_barrier")
}

fn contains_return(body: &[Stmt]) -> bool {
    body.iter().any(|s| match s {
        Stmt::Return { .. } => true,
        Stmt::If { then_body, else_body, .. } => {
            contains_return(then_body) || contains_return(else_body)
        }
        Stmt::For { body, .. } => contains_return(body),
        _ => false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_program;
    use crate::kernelmodel::launch::{GridGeom, WgGeom};

    fn lint(src: &str) -> LintReport {
        let prog = parse_program(src).expect("test kernel parses");
        let opts = SemaOptions {
            kernel: None,
            launch: Launch::new(WgGeom { w: 16, h: 16 }, GridGeom { w: 512, h: 512 }),
            bindings: Bindings::new().set("width", 512),
            certificates: false,
        };
        lint_program(&prog, &opts, &DeviceSpec::m2090()).expect("lint runs")
    }

    fn rules(r: &LintReport) -> Vec<(&'static str, Severity)> {
        r.diags.iter().map(|d| (d.rule.id(), d.severity)).collect()
    }

    #[test]
    fn uniform_barrier_is_clean() {
        let r = lint(
            "__kernel void k(__global float* a, int width) {
                 int x = get_global_id(0);
                 if (width > 64) { barrier(1); }
                 a[x] = 0.0f;
             }",
        );
        assert!(r.diags.is_empty(), "{:?}", rules(&r));
    }

    #[test]
    fn lane_variant_branch_barrier_denies() {
        let r = lint(
            "__kernel void k(__global float* a) {
                 int x = get_global_id(0);
                 if (x < 4) { barrier(1); }
                 a[x] = 0.0f;
             }",
        );
        assert_eq!(rules(&r), [("LM001", Severity::Deny)]);
    }

    #[test]
    fn lane_variant_loop_bound_barrier_denies() {
        let r = lint(
            "__kernel void k(__global float* a) {
                 int x = get_global_id(0);
                 for (int i = 0; i < x; i++) { barrier(1); }
                 a[x] = 0.0f;
             }",
        );
        assert_eq!(rules(&r), [("LM001", Severity::Deny)]);
    }

    #[test]
    fn divergent_early_return_then_barrier_denies() {
        let r = lint(
            "__kernel void k(__global float* a, int width) {
                 int x = get_global_id(0);
                 if (x >= width) { return; }
                 barrier(1);
                 a[x] = 0.0f;
             }",
        );
        assert_eq!(rules(&r), [("LM001", Severity::Deny)]);
    }

    #[test]
    fn assigned_under_divergent_branch_is_lane_variant() {
        let r = lint(
            "__kernel void k(__global float* a, int width) {
                 int x = get_global_id(0);
                 int t = 0;
                 if (x < 4) { t = 1; }
                 if (t > 0) { barrier(1); }
                 a[x] = 0.0f;
             }",
        );
        assert_eq!(rules(&r), [("LM001", Severity::Deny)]);
    }

    #[test]
    fn full_stride_column_tap_denies() {
        let r = lint(
            "__kernel void k(__global const float* in, __global float* out, int width) {
                 int gx = get_global_id(0);
                 int gy = get_global_id(1);
                 float s = 0.0f;
                 for (int t = 0; t < 600; t++) { s += in[gy * width + gx + t]; }
                 out[gy * width + gx] = s;
             }",
        );
        assert_eq!(rules(&r), [("LM002", Severity::Deny)]);
    }

    #[test]
    fn bank_conflicted_column_walk_warns_once() {
        let r = lint(
            "__kernel void k(__global const float* in, __global float* out, int width) {
                 int gx = get_global_id(0);
                 int gy = get_global_id(1);
                 out[gy * width + gx * 32] = in[gy * width + gx];
             }",
        );
        // LM004 fires; LM005 is suppressed on the same access.
        assert_eq!(rules(&r), [("LM004", Severity::Warn)]);
    }

    #[test]
    fn uncoalesced_in_loop_warns_one_off_notes() {
        let r = lint(
            "__kernel void k(__global const float* in, __global float* out, int width) {
                 int x = get_global_id(0);
                 int y = get_global_id(1);
                 float s = 0.0f;
                 for (int t = 0; t < 16; t++) { s += in[x * width + y + t]; }
                 out[x * width + y] = s;
             }",
        );
        assert_eq!(
            rules(&r),
            [("LM005", Severity::Warn), ("LM005", Severity::Note)]
        );
    }

    #[test]
    fn unbound_scalars_degrade_gracefully() {
        // No bindings for `n`: interval checks are skipped, divergence
        // still runs, nothing denies.
        let r = lint(
            "__kernel void k(__global const float* in, __global float* out, int n, int width) {
                 int x = get_global_id(0);
                 float s = 0.0f;
                 for (int t = 0; t < n; t++) { s += in[t * width + x]; }
                 out[x] = s;
             }",
        );
        assert!(r.diags.is_empty(), "{:?}", rules(&r));
    }

    #[test]
    fn unknown_kernel_name_errors() {
        let prog = parse_program("__kernel void k(__global float* a) { a[0] = 0.0f; }").unwrap();
        let opts = SemaOptions {
            kernel: Some("missing".into()),
            launch: Launch::new(WgGeom { w: 16, h: 16 }, GridGeom { w: 512, h: 512 }),
            bindings: Bindings::new(),
            certificates: false,
        };
        assert!(lint_program(&prog, &opts, &DeviceSpec::m2090()).is_err());
    }

    #[test]
    fn certificate_mixed_read_write_refuses() {
        let prog = parse_program(
            "__kernel void k(__global float* a, int width) {
                 int x = get_global_id(0);
                 int y = get_global_id(1);
                 a[y * width + x] = a[y * width + x] * 2.0f;
             }",
        )
        .unwrap();
        let opts = AnalyzeOptions {
            target: "a".into(),
            kernel: None,
            launch: Launch::new(WgGeom { w: 16, h: 16 }, GridGeom { w: 512, h: 512 }),
            bindings: Bindings::new().set("width", 512),
        };
        let cert = certify(&prog, &opts, &DeviceSpec::m2090());
        assert!(!cert.stageable);
        assert!(matches!(cert.reasons[0], CertReason::MixedReadWrite { loads: 1, stores: 1 }));
        assert!(cert.summary().starts_with("stageable: no"));
    }
}
