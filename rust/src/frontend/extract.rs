//! Descriptor synthesis: from a parsed kernel to a
//! [`KernelDescriptor`] + the canonical 18-feature vector.
//!
//! The extractor symbolically walks the kernel body once, binding every
//! integer-valued local to an [`Affine`] form over work-item intrinsics
//! and loop variables (scalar kernel arguments are bound to concrete
//! values via [`Bindings`] first). Every `__global` subscript must
//! reduce to an affine index; each one is recorded with its enclosing
//! loop nest, then the loops are classified against the *target* array
//! (see DESIGN.md §2d for the full contract):
//!
//! * **Work-unit (round) loop** — the loop variable strides past the
//!   work-item footprint: either cyclically (coefficient >= the grid
//!   span of the coordinate's work-item part, the paper's §4.1 cyclic
//!   distribution) or as an exact blocked tile (unit coefficient,
//!   zero-based, trip == the work-item coefficient). Trips multiply
//!   into `wus_per_wi`.
//! * **Tap loop** — the variable offsets a work-item-dependent home by
//!   bounded constants (a stencil expressed as a loop). The loop is
//!   unrolled: trips multiply into the tap count and its value range
//!   becomes tap offsets.
//! * **Inner loop** — the variable *is* the home position in some
//!   coordinate (no work-item term). The innermost such loops multiply
//!   into `inner_iters`; when two or more nest, the outermost is the
//!   round loop (`matrixMul`'s k-tile loop over tiles).
//!
//! Computation is counted in FMA-equivalents (a multiply feeding an
//! add/sub counts once), excluding subscript arithmetic; contextual
//! (non-target) global accesses are split coalesced/non-coalesced by
//! `access::tx_per_access` and inner-loop-body/epilogue by loop nest
//! (loads outside any inner/tap loop count as body work when
//! `inner_iters == 1`, matching the template model's accounting).
//! `__constant` reads ride the constant cache and are not counted.
//!
//! Every failure is a typed, positioned [`ExtractError`]; nothing in
//! this module panics on user input.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use super::access::{split_row_col, tx_per_access, Affine, RowCol, Var};
use super::ast::{AddrSpace, AssignOp, BinOp, Expr, ForStep, Kernel, Program, Stmt};
use super::lexer::Pos;

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;
use crate::kernelmodel::launch::Launch;
use crate::workloads::DescriptorBuilder;

/// Loops longer than this are rejected (they would make the unrolled
/// model meaningless and the arithmetic overflow-prone).
pub const MAX_TRIP: u64 = 1 << 20;

/// Register-estimate heuristic: base + 2 per declared scalar local +
/// one per 4 taps (live stencil operands). Reconciled against the
/// hand-mapped workloads within +-8 registers (DESIGN.md §2d).
pub const REG_BASE: u32 = 8;

/// Extra registers the staging transform costs (address arithmetic for
/// the cooperative copy) — matches the hand-mapped workloads.
pub const OPT_EXTRA_REGS: u32 = 4;

/// Concrete values for scalar kernel arguments (`--set name=value`).
#[derive(Clone, Debug, Default)]
pub struct Bindings {
    map: BTreeMap<String, i64>,
}

impl Bindings {
    pub fn new() -> Bindings {
        Bindings::default()
    }

    /// Builder-style insert.
    pub fn set(mut self, name: &str, value: i64) -> Bindings {
        self.map.insert(name.to_string(), value);
        self
    }

    pub fn insert(&mut self, name: &str, value: i64) {
        self.map.insert(name.to_string(), value);
    }

    pub fn get(&self, name: &str) -> Option<i64> {
        self.map.get(name).copied()
    }

    /// Parse a `name=value,name=value` list (the CLI `--set` format).
    pub fn parse(s: &str) -> Result<Bindings, String> {
        let mut b = Bindings::new();
        for part in s.split(',').filter(|p| !p.trim().is_empty()) {
            let (name, value) = part
                .split_once('=')
                .ok_or_else(|| format!("`{part}`: expected name=value"))?;
            let v: i64 = value.trim().parse().map_err(|e| format!("`{part}`: {e}"))?;
            b.insert(name.trim(), v);
        }
        Ok(b)
    }
}

/// What to analyze: which kernel, which array to consider staging, the
/// launch configuration, and scalar-argument values.
#[derive(Clone, Debug)]
pub struct AnalyzeOptions {
    pub target: String,
    /// Kernel name; `None` is allowed when the file holds exactly one.
    pub kernel: Option<String>,
    pub launch: Launch,
    pub bindings: Bindings,
}

#[derive(Clone, Debug)]
pub enum ExtractErrorKind {
    NoKernels,
    UnknownKernel { name: String, available: Vec<String> },
    AmbiguousKernel { available: Vec<String> },
    UnknownArray { name: String, available: Vec<String> },
    TargetNotGlobal { name: String },
    TargetNeverAccessed { name: String },
    UsesLocalMemory,
    UnboundParam { name: String },
    UnknownIdent { name: String },
    NonAffine { what: String },
    UnsupportedLoop { what: String },
    MixedStride { what: String },
    InvalidLaunch { what: String },
    DivByZero,
    TooLarge { what: String },
    Unsupported { what: String },
}

/// Typed, positioned analysis error.
#[derive(Clone, Debug)]
pub struct ExtractError {
    pub pos: Pos,
    pub kind: ExtractErrorKind,
}

impl fmt::Display for ExtractError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use ExtractErrorKind::*;
        write!(f, "analysis error at {}: ", self.pos)?;
        match &self.kind {
            NoKernels => write!(f, "source contains no __kernel definitions"),
            UnknownKernel { name, available } => {
                write!(f, "no kernel named `{name}` (available: {})", available.join(", "))
            }
            AmbiguousKernel { available } => write!(
                f,
                "multiple kernels in file — pick one with --kernel ({})",
                available.join(", ")
            ),
            UnknownArray { name, available } => write!(
                f,
                "no __global array parameter named `{name}` (arrays: {})",
                available.join(", ")
            ),
            TargetNotGlobal { name } => {
                write!(f, "target array `{name}` is not in the __global address space")
            }
            TargetNeverAccessed { name } => {
                write!(f, "target array `{name}` is never subscripted in the kernel body")
            }
            UsesLocalMemory => write!(
                f,
                "kernel already uses __local memory — analyze the unoptimized \
                 (unstaged) kernel"
            ),
            UnboundParam { name } => write!(
                f,
                "scalar argument `{name}` is used in an index or loop bound but \
                 has no value — bind it with --set {name}=<int>"
            ),
            UnknownIdent { name } => write!(f, "unknown identifier `{name}`"),
            NonAffine { what } => write!(
                f,
                "{what} is not an affine function of work-item ids and loop \
                 variables"
            ),
            UnsupportedLoop { what } => write!(f, "unsupported loop: {what}"),
            MixedStride { what } => write!(f, "{what}"),
            InvalidLaunch { what } => write!(f, "invalid launch configuration: {what}"),
            DivByZero => write!(f, "division by zero in a constant expression"),
            TooLarge { what } => write!(f, "{what}"),
            Unsupported { what } => write!(f, "{what} is not supported"),
        }
    }
}

impl std::error::Error for ExtractError {}

fn err<T>(pos: Pos, kind: ExtractErrorKind) -> Result<T, ExtractError> {
    Err(ExtractError { pos, kind })
}

// ---------------------------------------------------------------------
// Symbolic walk.

#[derive(Clone, Debug)]
enum Val {
    Aff(Affine),
    Opaque,
    /// A scalar kernel argument with no binding: usable as data, an
    /// error (naming the argument) if it reaches an index or bound.
    Unbound(String),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Role {
    Wu,
    Inner,
    Tap,
    Other,
}

/// A fully-resolved counted loop: shared with the sema pass
/// ([`super::sema`]), which reuses the extractor's symbolic binding
/// machinery for its interval checks.
#[derive(Clone, Debug)]
pub(crate) struct LoopCtx {
    pub(crate) start: i64,
    pub(crate) step: i64,
    pub(crate) trip: u64,
    /// Nesting depth at creation (outermost = 0).
    pub(crate) depth: usize,
}

impl LoopCtx {
    /// Smallest / largest value the loop variable takes (i128: the
    /// product cannot wrap even for absurd user-chosen steps).
    pub(crate) fn value_range(&self) -> (i128, i128) {
        let start = self.start as i128;
        let last = start + (self.trip as i128 - 1) * self.step as i128;
        (start.min(last), start.max(last))
    }
}

#[derive(Clone, Debug)]
struct Site {
    array: String,
    index: Affine,
    is_store: bool,
    loops: Vec<u32>,
    pos: Pos,
}

#[derive(Clone, Debug)]
struct CompRec {
    ops: u32,
    loops: Vec<u32>,
}

struct Walker<'a> {
    env: BTreeMap<String, Val>,
    arrays: BTreeMap<String, AddrSpace>,
    launch: Launch,
    loops: Vec<LoopCtx>,
    stack: Vec<u32>,
    sites: Vec<Site>,
    comps: Vec<CompRec>,
    decls: u32,
    bindings: &'a Bindings,
}

type EResult<T> = Result<T, ExtractError>;

impl<'a> Walker<'a> {
    fn overflow<T>(pos: Pos) -> EResult<T> {
        err(pos, ExtractErrorKind::TooLarge { what: "index arithmetic overflows i64".into() })
    }

    fn eval(&mut self, e: &Expr) -> EResult<Val> {
        match e {
            Expr::Int(v, _) => Ok(Val::Aff(Affine::constant(*v))),
            Expr::Float(..) => Ok(Val::Opaque),
            Expr::Var(name, pos) => match self.env.get(name) {
                Some(v) => Ok(v.clone()),
                None => err(*pos, ExtractErrorKind::UnknownIdent { name: name.clone() }),
            },
            Expr::Call { name, args, pos } => self.eval_call(name, args, *pos),
            Expr::Index { base, index, pos } => {
                let array = match base.as_ref() {
                    Expr::Var(name, _) => name.clone(),
                    Expr::Index { .. } => {
                        return err(
                            *pos,
                            ExtractErrorKind::Unsupported {
                                what: "nested subscripts (multi-dimensional arrays)".into(),
                            },
                        )
                    }
                    _ => {
                        return err(
                            *pos,
                            ExtractErrorKind::Unsupported {
                                what: "subscripting a non-identifier expression".into(),
                            },
                        )
                    }
                };
                self.record_access(&array, index, false, *pos)?;
                Ok(Val::Opaque)
            }
            Expr::Unary { op, expr, pos } => {
                let v = self.eval(expr)?;
                match (*op, v) {
                    ('-', Val::Aff(a)) => match a.neg() {
                        Ok(n) => Ok(Val::Aff(n)),
                        Err(_) => Self::overflow(*pos),
                    },
                    (_, Val::Unbound(n)) => Ok(Val::Unbound(n)),
                    _ => Ok(Val::Opaque),
                }
            }
            Expr::Bin { op, lhs, rhs, pos } => {
                let l = self.eval(lhs)?;
                let r = self.eval(rhs)?;
                self.eval_bin(*op, l, r, *pos)
            }
        }
    }

    fn eval_bin(&mut self, op: BinOp, l: Val, r: Val, pos: Pos) -> EResult<Val> {
        if !op.is_arith() {
            // Comparisons / logical ops produce booleans we never index by.
            return Ok(Val::Opaque);
        }
        // Unbound arguments poison the expression with their name so the
        // eventual index/bound error can say which `--set` is missing.
        if let Val::Unbound(n) = &l {
            return Ok(Val::Unbound(n.clone()));
        }
        if let Val::Unbound(n) = &r {
            return Ok(Val::Unbound(n.clone()));
        }
        let (a, b) = match (l, r) {
            (Val::Aff(a), Val::Aff(b)) => (a, b),
            _ => return Ok(Val::Opaque),
        };
        let out = match op {
            BinOp::Add => a.add(&b),
            BinOp::Sub => a.sub(&b),
            BinOp::Mul => {
                if let Some(k) = b.as_const() {
                    a.scale(k)
                } else if let Some(k) = a.as_const() {
                    b.scale(k)
                } else {
                    return Ok(Val::Opaque);
                }
            }
            BinOp::Div => match b.as_const() {
                Some(0) => return err(pos, ExtractErrorKind::DivByZero),
                Some(k) => {
                    if let Some(c) = a.as_const() {
                        // checked: i64::MIN / -1 would abort otherwise.
                        return match c.checked_div(k) {
                            Some(v) => Ok(Val::Aff(Affine::constant(v))),
                            None => Self::overflow(pos),
                        };
                    }
                    match a.div_exact(k) {
                        Some(d) => return Ok(Val::Aff(d)),
                        None => return Ok(Val::Opaque),
                    }
                }
                None => return Ok(Val::Opaque),
            },
            BinOp::Rem => match (a.as_const(), b.as_const()) {
                (_, Some(0)) => return err(pos, ExtractErrorKind::DivByZero),
                (Some(x), Some(k)) => {
                    return match x.checked_rem(k) {
                        Some(v) => Ok(Val::Aff(Affine::constant(v))),
                        None => Self::overflow(pos),
                    }
                }
                _ => return Ok(Val::Opaque),
            },
            _ => unreachable!("non-arith handled above"),
        };
        match out {
            Ok(a) => Ok(Val::Aff(a)),
            Err(_) => Self::overflow(pos),
        }
    }

    /// The `0`/`1` dimension argument of a work-item intrinsic.
    fn dim_arg(&mut self, name: &str, args: &[Expr], pos: Pos) -> EResult<u8> {
        if args.len() != 1 {
            return err(
                pos,
                ExtractErrorKind::Unsupported {
                    what: format!("`{name}` with {} arguments", args.len()),
                },
            );
        }
        match self.eval(&args[0])? {
            Val::Aff(a) if a.as_const() == Some(0) => Ok(0),
            Val::Aff(a) if a.as_const() == Some(1) => Ok(1),
            _ => err(
                pos,
                ExtractErrorKind::Unsupported {
                    what: format!("`{name}` dimension other than the constant 0 or 1"),
                },
            ),
        }
    }

    fn eval_call(&mut self, name: &str, args: &[Expr], pos: Pos) -> EResult<Val> {
        match name {
            "get_global_id" => {
                let d = self.dim_arg(name, args, pos)?;
                Ok(Val::Aff(Affine::var(Var::Gid(d))))
            }
            "get_local_id" => {
                let d = self.dim_arg(name, args, pos)?;
                Ok(Val::Aff(Affine::var(Var::Lid(d))))
            }
            "get_group_id" => {
                let d = self.dim_arg(name, args, pos)?;
                Ok(Val::Aff(Affine::var(Var::Group(d))))
            }
            "get_local_size" => {
                let d = self.dim_arg(name, args, pos)?;
                let wg = self.launch.wg;
                let v = if d == 0 { wg.w } else { wg.h };
                Ok(Val::Aff(Affine::constant(v as i64)))
            }
            "get_global_size" => {
                let d = self.dim_arg(name, args, pos)?;
                let grid = self.launch.grid;
                let v = if d == 0 { grid.w } else { grid.h };
                Ok(Val::Aff(Affine::constant(v as i64)))
            }
            "get_num_groups" => {
                let d = self.dim_arg(name, args, pos)?;
                let l = self.launch;
                let v = if d == 0 { l.groups_x() } else { l.groups_y() };
                Ok(Val::Aff(Affine::constant(v as i64)))
            }
            _ => {
                // Math builtins etc.: walk the arguments (they may contain
                // global accesses), result is opaque data.
                for a in args {
                    self.eval(a)?;
                }
                Ok(Val::Opaque)
            }
        }
    }

    fn record_access(
        &mut self,
        array: &str,
        index: &Expr,
        is_store: bool,
        pos: Pos,
    ) -> EResult<()> {
        let space = match self.arrays.get(array) {
            Some(s) => *s,
            None => {
                return if self.env.contains_key(array) {
                    err(
                        pos,
                        ExtractErrorKind::Unsupported {
                            what: format!("subscripting scalar `{array}`"),
                        },
                    )
                } else {
                    err(pos, ExtractErrorKind::UnknownIdent { name: array.to_string() })
                }
            }
        };
        match space {
            AddrSpace::Local => return err(pos, ExtractErrorKind::UsesLocalMemory),
            AddrSpace::Constant => {
                if is_store {
                    return err(
                        pos,
                        ExtractErrorKind::Unsupported {
                            what: format!("storing to __constant array `{array}`"),
                        },
                    );
                }
                // Constant-cache reads are free context; index shape is
                // irrelevant, but still walk it for nested accesses.
                self.eval(index)?;
                return Ok(());
            }
            // Private pointers are rejected at parameter binding.
            AddrSpace::Global | AddrSpace::Private => {}
        }
        let idx = match self.eval(index)? {
            Val::Aff(a) => a,
            Val::Unbound(n) => return err(pos, ExtractErrorKind::UnboundParam { name: n }),
            Val::Opaque => {
                return err(
                    pos,
                    ExtractErrorKind::NonAffine {
                        what: format!("the subscript of `{array}`"),
                    },
                )
            }
        };
        self.sites.push(Site {
            array: array.to_string(),
            index: idx,
            is_store,
            loops: self.stack.clone(),
            pos,
        });
        Ok(())
    }

    /// FMA-equivalent op count of an expression, excluding subscript
    /// arithmetic: a multiply feeding an add/sub fuses to one op.
    fn count_ops(e: &Expr) -> u32 {
        fn is_mul(e: &Expr) -> bool {
            matches!(e, Expr::Bin { op: BinOp::Mul, .. })
        }
        match e {
            Expr::Bin { op, lhs, rhs, .. } if op.is_arith() => {
                let mut n = Self::count_ops(lhs) + Self::count_ops(rhs) + 1;
                if matches!(op, BinOp::Add | BinOp::Sub) && (is_mul(lhs) || is_mul(rhs)) {
                    n -= 1;
                }
                n
            }
            Expr::Bin { lhs, rhs, .. } => Self::count_ops(lhs) + Self::count_ops(rhs),
            Expr::Unary { expr, .. } => Self::count_ops(expr),
            Expr::Call { args, .. } => args.iter().map(Self::count_ops).sum(),
            Expr::Index { .. } => 0,
            Expr::Int(..) | Expr::Float(..) | Expr::Var(..) => 0,
        }
    }

    fn push_comp(&mut self, ops: u32) {
        if ops > 0 {
            self.comps.push(CompRec { ops, loops: self.stack.clone() });
        }
    }

    fn walk(&mut self, body: &[Stmt]) -> EResult<()> {
        for s in body {
            self.walk_stmt(s)?;
        }
        Ok(())
    }

    fn walk_stmt(&mut self, s: &Stmt) -> EResult<()> {
        match s {
            Stmt::Decl { name, init, .. } => {
                self.decls += 1;
                let v = match init {
                    Some(e) => {
                        self.push_comp(Self::count_ops(e));
                        self.eval(e)?
                    }
                    None => Val::Opaque,
                };
                self.env.insert(name.clone(), v);
                Ok(())
            }
            Stmt::Assign { target, op, value, pos } => {
                let mut ops = Self::count_ops(value);
                if *op != AssignOp::Set {
                    ops += 1;
                    // `x += a*b` is one FMA, not mul-then-add.
                    if matches!(op, AssignOp::Add | AssignOp::Sub)
                        && matches!(value, Expr::Bin { op: BinOp::Mul, .. })
                    {
                        ops -= 1;
                    }
                }
                self.push_comp(ops);
                let rhs = self.eval(value)?;
                match target {
                    Expr::Index { base, index, pos } => {
                        let array = match base.as_ref() {
                            Expr::Var(name, _) => name.clone(),
                            _ => {
                                return err(
                                    *pos,
                                    ExtractErrorKind::Unsupported {
                                        what: "nested subscripts (multi-dimensional arrays)"
                                            .into(),
                                    },
                                )
                            }
                        };
                        self.record_access(&array, index, true, *pos)
                    }
                    Expr::Var(name, vpos) => {
                        let old = match self.env.get(name) {
                            Some(v) => v.clone(),
                            None => {
                                return err(
                                    *vpos,
                                    ExtractErrorKind::UnknownIdent { name: name.clone() },
                                )
                            }
                        };
                        let new = match op {
                            AssignOp::Set => rhs,
                            AssignOp::Add => self.eval_bin(BinOp::Add, old, rhs, *pos)?,
                            AssignOp::Sub => self.eval_bin(BinOp::Sub, old, rhs, *pos)?,
                            AssignOp::Mul => self.eval_bin(BinOp::Mul, old, rhs, *pos)?,
                            AssignOp::Div => self.eval_bin(BinOp::Div, old, rhs, *pos)?,
                        };
                        self.env.insert(name.clone(), new);
                        Ok(())
                    }
                    other => err(
                        other.pos(),
                        ExtractErrorKind::Unsupported {
                            what: "assignment to a non-lvalue".into(),
                        },
                    ),
                }
            }
            Stmt::For { var, init, cond_op, bound, step, body, pos, .. } => {
                self.walk_for(var, init, *cond_op, bound, step, body, *pos)
            }
            Stmt::If { cond, then_body, else_body, .. } => {
                self.eval(cond)?;
                // Both branches are assumed executed (guards in the
                // supported kernels are boundary checks, not control of
                // the access pattern); variables they write become opaque.
                let mut assigned = BTreeSet::new();
                assigned_scalars(then_body, &mut assigned);
                assigned_scalars(else_body, &mut assigned);
                let saved = self.env.clone();
                self.walk(then_body)?;
                self.env = saved.clone();
                self.walk(else_body)?;
                self.env = saved;
                self.mark_opaque(&assigned);
                Ok(())
            }
            Stmt::Call { args, .. } => {
                for a in args {
                    self.eval(a)?;
                }
                Ok(())
            }
            Stmt::Return { .. } => Ok(()),
        }
    }

    fn mark_opaque(&mut self, names: &BTreeSet<String>) {
        for n in names {
            if self.env.contains_key(n) {
                self.env.insert(n.clone(), Val::Opaque);
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn walk_for(
        &mut self,
        var: &str,
        init: &Expr,
        cond_op: BinOp,
        bound: &Expr,
        step: &ForStep,
        body: &[Stmt],
        pos: Pos,
    ) -> EResult<()> {
        let start = self.const_of(init, "the loop start")?;
        let bound_v = self.const_of(bound, "the loop bound")?;
        let step_v: i64 = match step {
            ForStep::Inc => 1,
            ForStep::Dec => -1,
            ForStep::Add(e) => self.const_of(e, "the loop step")?,
            ForStep::Sub(e) => {
                let v = self.const_of(e, "the loop step")?;
                v.checked_neg().ok_or(ExtractError {
                    pos,
                    kind: ExtractErrorKind::TooLarge {
                        what: "loop step out of range".into(),
                    },
                })?
            }
        };
        if step_v == 0 {
            return err(pos, ExtractErrorKind::UnsupportedLoop { what: "zero step".into() });
        }
        let trip = match trip_count(start, bound_v, step_v, cond_op) {
            Some(t) => t,
            None => {
                return err(
                    pos,
                    ExtractErrorKind::UnsupportedLoop {
                        what: format!(
                            "step direction `{}` never reaches the `{}` bound",
                            if step_v > 0 { "+" } else { "-" },
                            cond_op.as_str()
                        ),
                    },
                )
            }
        };
        if trip == 0 {
            return Ok(()); // body never executes
        }
        if trip > MAX_TRIP {
            return err(
                pos,
                ExtractErrorKind::TooLarge {
                    what: format!("loop trip count {trip} exceeds the supported {MAX_TRIP}"),
                },
            );
        }
        // Induction variables other than the counter are not modeled:
        // anything the body assigns is opaque inside (and after) it.
        let mut assigned = BTreeSet::new();
        assigned_scalars(body, &mut assigned);
        let saved = self.env.clone();
        self.mark_opaque(&assigned);
        let id = self.loops.len() as u32;
        self.loops.push(LoopCtx { start, step: step_v, trip, depth: self.stack.len() });
        self.env.insert(var.to_string(), Val::Aff(Affine::var(Var::Loop(id))));
        self.stack.push(id);
        let res = self.walk(body);
        self.stack.pop();
        self.env = saved;
        self.mark_opaque(&assigned);
        res
    }
}

impl<'a> Walker<'a> {
    /// Evaluate an expression that must fold to a compile-time constant
    /// (loop starts, bounds and steps).
    fn const_of(&mut self, e: &Expr, what: &str) -> EResult<i64> {
        match self.eval(e)? {
            Val::Aff(a) => match a.as_const() {
                Some(v) => Ok(v),
                None => err(
                    e.pos(),
                    ExtractErrorKind::UnsupportedLoop {
                        what: format!("{what} must be constant after binding scalar arguments"),
                    },
                ),
            },
            Val::Unbound(n) => err(e.pos(), ExtractErrorKind::UnboundParam { name: n }),
            Val::Opaque => err(
                e.pos(),
                ExtractErrorKind::UnsupportedLoop {
                    what: format!("{what} must be constant after binding scalar arguments"),
                },
            ),
        }
    }
}

/// Trip count of `for (v = start; v <cond_op> bound; v += step)`:
/// `None` when the step direction never reaches the bound. `step` must
/// be nonzero. i128 arithmetic so user-chosen extremes cannot wrap in
/// release builds. Shared with the sema pass, which tolerates loops the
/// extractor rejects.
pub(crate) fn trip_count(start: i64, bound: i64, step: i64, cond_op: BinOp) -> Option<u64> {
    debug_assert!(step != 0);
    let up = step > 0;
    let s = step as i128;
    let diff = bound as i128 - start as i128;
    let trip: i128 = match (cond_op, up) {
        (BinOp::Lt, true) => (diff + s - 1).div_euclid(s),
        (BinOp::Le, true) => diff.div_euclid(s) + 1,
        (BinOp::Gt, false) => (diff + s + 1).div_euclid(s),
        (BinOp::Ge, false) => diff.div_euclid(s) + 1,
        _ => return None,
    };
    Some(trip.max(0).min(u64::MAX as i128) as u64)
}

/// Names assigned (not declared) anywhere in `body`, recursively.
pub(crate) fn assigned_scalars(body: &[Stmt], out: &mut BTreeSet<String>) {
    for s in body {
        match s {
            Stmt::Assign { target: Expr::Var(name, _), .. } => {
                out.insert(name.clone());
            }
            Stmt::For { body, .. } => assigned_scalars(body, out),
            Stmt::If { then_body, else_body, .. } => {
                assigned_scalars(then_body, out);
                assigned_scalars(else_body, out);
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------
// Post-walk classification & synthesis.

struct GlobalSite {
    site: Site,
    rc: RowCol,
}

fn select_kernel<'p>(prog: &'p Program, opts: &AnalyzeOptions) -> EResult<&'p Kernel> {
    let names: Vec<String> = prog.kernels.iter().map(|k| k.name.clone()).collect();
    if prog.kernels.is_empty() {
        return err(Pos::start(), ExtractErrorKind::NoKernels);
    }
    match &opts.kernel {
        Some(want) => prog
            .kernels
            .iter()
            .find(|k| &k.name == want)
            .ok_or(ExtractError {
                pos: Pos::start(),
                kind: ExtractErrorKind::UnknownKernel { name: want.clone(), available: names },
            }),
        None if prog.kernels.len() == 1 => Ok(&prog.kernels[0]),
        None => err(prog.kernels[1].pos, ExtractErrorKind::AmbiguousKernel { available: names }),
    }
}

/// Descriptor plus the target array's static access-site counts — what
/// the staging certifier ([`super::sema::certify`]) needs on top of the
/// descriptor itself: a region that is both read and written between
/// barriers cannot be staged safely.
#[derive(Clone, Debug)]
pub struct TargetProfile {
    pub descriptor: KernelDescriptor,
    /// Static load sites on the target array (not dynamic counts).
    pub target_loads: u32,
    /// Static store sites on the target array.
    pub target_stores: u32,
}

/// Analyze `prog` and synthesize the kernel descriptor for the given
/// target array, launch and device.
pub fn extract_descriptor(
    prog: &Program,
    opts: &AnalyzeOptions,
    dev: &DeviceSpec,
) -> EResult<KernelDescriptor> {
    extract_profile(prog, opts, dev).map(|p| p.descriptor)
}

/// [`extract_descriptor`] plus the target's load/store site counts.
pub fn extract_profile(
    prog: &Program,
    opts: &AnalyzeOptions,
    dev: &DeviceSpec,
) -> EResult<TargetProfile> {
    let kernel = select_kernel(prog, opts)?;
    let launch = opts.launch;
    if !launch.valid() {
        return err(
            kernel.pos,
            ExtractErrorKind::InvalidLaunch {
                what: format!(
                    "workgroup {}x{} must divide grid {}x{}",
                    launch.wg.w, launch.wg.h, launch.grid.w, launch.grid.h
                ),
            },
        );
    }
    if launch.wg.size() > dev.max_threads_per_block {
        return err(
            kernel.pos,
            ExtractErrorKind::InvalidLaunch {
                what: format!(
                    "workgroup {}x{} exceeds {} threads/block on {}",
                    launch.wg.w,
                    launch.wg.h,
                    dev.max_threads_per_block,
                    dev.key
                ),
            },
        );
    }

    // Parameter environment: pointers become arrays, bound integer
    // scalars become constants, everything else is opaque data.
    let mut walker = Walker {
        env: BTreeMap::new(),
        arrays: BTreeMap::new(),
        launch,
        loops: Vec::new(),
        stack: Vec::new(),
        sites: Vec::new(),
        comps: Vec::new(),
        decls: 0,
        bindings: &opts.bindings,
    };
    let mut array_names = Vec::new();
    for p in &kernel.params {
        if p.is_ptr {
            match p.space {
                AddrSpace::Local => {
                    return err(p.pos, ExtractErrorKind::UsesLocalMemory);
                }
                AddrSpace::Private => {
                    // Kernel pointer args must carry an address space in
                    // OpenCL; don't guess which memory they alias.
                    return err(
                        p.pos,
                        ExtractErrorKind::Unsupported {
                            what: format!(
                                "unqualified pointer parameter `{}` (declare it \
                                 __global or __constant)",
                                p.name
                            ),
                        },
                    );
                }
                AddrSpace::Global | AddrSpace::Constant => {}
            }
            walker.arrays.insert(p.name.clone(), p.space);
            if p.space == AddrSpace::Global {
                array_names.push(p.name.clone());
            }
        } else {
            let v = match walker.bindings.get(&p.name) {
                Some(v) if is_int_type(&p.ty) => Val::Aff(Affine::constant(v)),
                _ if is_int_type(&p.ty) => Val::Unbound(p.name.clone()),
                _ => Val::Opaque,
            };
            walker.env.insert(p.name.clone(), v);
        }
    }
    match walker.arrays.get(&opts.target) {
        None => {
            return err(
                kernel.pos,
                ExtractErrorKind::UnknownArray {
                    name: opts.target.clone(),
                    available: array_names,
                },
            )
        }
        Some(AddrSpace::Global) => {}
        Some(_) => {
            return err(kernel.pos, ExtractErrorKind::TargetNotGlobal { name: opts.target.clone() })
        }
    }

    walker.walk(&kernel.body)?;

    // Decompose every global access into 2D coordinates.
    let mut globals: Vec<GlobalSite> = Vec::new();
    for site in std::mem::take(&mut walker.sites) {
        let rc = split_row_col(&site.index).map_err(|msg| ExtractError {
            pos: site.pos,
            kind: ExtractErrorKind::MixedStride { what: format!("`{}`: {msg}", site.array) },
        })?;
        globals.push(GlobalSite { site, rc });
    }
    let target_sites: Vec<&GlobalSite> =
        globals.iter().filter(|g| g.site.array == opts.target).collect();
    if target_sites.is_empty() {
        return err(kernel.pos, ExtractErrorKind::TargetNeverAccessed { name: opts.target.clone() });
    }

    let roles = classify_loops(&walker.loops, &target_sites, &launch);
    let target_loads = target_sites.iter().filter(|g| !g.site.is_store).count() as u32;
    let target_stores = target_sites.iter().filter(|g| g.site.is_store).count() as u32;
    let descriptor = synthesize(kernel, dev, &launch, &walker, &globals, &target_sites, &roles)?;
    Ok(TargetProfile { descriptor, target_loads, target_stores })
}

pub(crate) fn is_int_type(ty: &str) -> bool {
    matches!(ty, "int" | "uint" | "long" | "ulong" | "short" | "size_t" | "char")
}

/// Classify every loop against the target tap set (module docs / DESIGN
/// §2d). Loops the target never depends on but that enclose target
/// accesses are Inner (the same elements are re-accessed every
/// iteration); loops not enclosing any target access are Other.
fn classify_loops(loops: &[LoopCtx], target_sites: &[&GlobalSite], launch: &Launch) -> Vec<Role> {
    let grid_span = |a: &Affine| -> i64 {
        let gx = (launch.grid.w as i64 - 1).max(0);
        let gy = (launch.grid.h as i64 - 1).max(0);
        let x_span = a.wi_coeff(0).abs().saturating_mul(gx);
        x_span.saturating_add(a.wi_coeff(1).abs().saturating_mul(gy))
    };
    let mut roles: Vec<Option<Role>> = vec![None; loops.len()];
    let mut encloses_target = vec![false; loops.len()];
    let mut home_votes: Vec<bool> = vec![false; loops.len()];
    for g in target_sites {
        for &lid in &g.site.loops {
            encloses_target[lid as usize] = true;
        }
        for coord in [&g.rc.row, &g.rc.col] {
            for (v, lc) in &coord.terms {
                let lid = match v {
                    Var::Loop(i) => *i as usize,
                    _ => continue,
                };
                let info = &loops[lid];
                if coord.depends_on_wi() {
                    let span = grid_span(coord);
                    let cyclic = lc.abs() >= span.saturating_add(1);
                    let cw = if coord.wi_coeff(0) != 0 {
                        coord.wi_coeff(0).abs()
                    } else {
                        coord.wi_coeff(1).abs()
                    };
                    let blocked = lc.abs() == 1
                        && info.start == 0
                        && info.step == 1
                        && info.trip as i64 == cw;
                    if cyclic || blocked {
                        // Wu only if no stronger (Tap) vote exists.
                        if roles[lid] != Some(Role::Tap) {
                            roles[lid] = Some(Role::Wu);
                        }
                    } else {
                        roles[lid] = Some(Role::Tap);
                    }
                } else {
                    home_votes[lid] = true;
                }
            }
        }
    }
    // Home-driving loops: Inner by default; when several nest, the
    // outermost is the round loop.
    let home: Vec<usize> = (0..loops.len())
        .filter(|&i| roles[i].is_none() && home_votes[i])
        .collect();
    if home.len() >= 2 {
        let min_depth = home.iter().map(|&i| loops[i].depth).min().unwrap_or(0);
        let outermost: Vec<usize> =
            home.iter().copied().filter(|&i| loops[i].depth == min_depth).collect();
        for &i in &home {
            roles[i] = Some(if outermost.len() == 1 && outermost[0] == i {
                Role::Wu
            } else {
                Role::Inner
            });
        }
    } else {
        for &i in &home {
            roles[i] = Some(Role::Inner);
        }
    }
    (0..loops.len())
        .map(|i| match roles[i] {
            Some(r) => r,
            None if encloses_target[i] => Role::Inner,
            None => Role::Other,
        })
        .collect()
}

/// Interval of a coordinate over one workgroup and one round: work-item
/// ids span the workgroup, inner/tap/other loop variables span their
/// ranges, round (Wu) loops and group ids are fixed.
fn coord_interval(a: &Affine, launch: &Launch, loops: &[LoopCtx], roles: &[Role]) -> (i128, i128) {
    let mut lo = a.c as i128;
    let mut hi = a.c as i128;
    for (v, c) in &a.terms {
        // Contribution interval of this term over one round.
        let (d0, d1): (i128, i128) = match v {
            Var::Gid(0) | Var::Lid(0) => (0, (*c as i128) * (launch.wg.w as i128 - 1)),
            Var::Gid(1) | Var::Lid(1) => (0, (*c as i128) * (launch.wg.h as i128 - 1)),
            Var::Gid(_) | Var::Lid(_) | Var::Group(_) => (0, 0),
            Var::Loop(i) => {
                if roles[*i as usize] == Role::Wu {
                    (0, 0)
                } else {
                    let (mn, mx) = loops[*i as usize].value_range();
                    ((*c as i128) * mn, (*c as i128) * mx)
                }
            }
        };
        lo += d0.min(d1);
        hi += d0.max(d1);
    }
    (lo, hi)
}

/// Tap-offset interval of a coordinate: constants plus tap-loop spans
/// (work-item home and round/inner positions excluded).
fn offset_interval(a: &Affine, loops: &[LoopCtx], roles: &[Role]) -> (i128, i128) {
    let mut lo = a.c as i128;
    let mut hi = a.c as i128;
    for (v, c) in &a.terms {
        if let Var::Loop(i) = v {
            if roles[*i as usize] == Role::Tap {
                let (mn, mx) = loops[*i as usize].value_range();
                let d0 = (*c as i128) * mn;
                let d1 = (*c as i128) * mx;
                lo += d0.min(d1);
                hi += d0.max(d1);
            }
        }
    }
    (lo, hi)
}

fn product_of(loop_ids: &[u32], loops: &[LoopCtx], roles: &[Role], keep: &[Role]) -> Option<u64> {
    let mut p: u64 = 1;
    for &id in loop_ids {
        if keep.contains(&roles[id as usize]) {
            p = p.checked_mul(loops[id as usize].trip)?;
        }
    }
    Some(p)
}

fn synthesize(
    kernel: &Kernel,
    dev: &DeviceSpec,
    launch: &Launch,
    walker: &Walker<'_>,
    globals: &[GlobalSite],
    target_sites: &[&GlobalSite],
    roles: &[Role],
) -> EResult<KernelDescriptor> {
    let loops = &walker.loops;
    let kpos = kernel.pos;
    let too_large = |what: &str| ExtractError {
        pos: kpos,
        kind: ExtractErrorKind::TooLarge { what: what.to_string() },
    };
    let seg = (dev.transaction_bytes / 4).max(1);

    // Work units & inner iterations: products over the classified loops.
    let all_ids: Vec<u32> = (0..loops.len() as u32).collect();
    let wus_per_wi = product_of(&all_ids, loops, roles, &[Role::Wu])
        .ok_or_else(|| too_large("work-unit rounds overflow"))?;
    let inner_iters = product_of(&all_ids, loops, roles, &[Role::Inner])
        .ok_or_else(|| too_large("inner iteration count overflows"))?;

    // Tap set: multiplicity, offsets, average transactions, footprint.
    let mut taps: u64 = 0;
    let mut tx_weighted = 0.0f64;
    let mut off = (i128::MAX, i128::MIN, i128::MAX, i128::MIN);
    let mut region = (i128::MAX, i128::MIN, i128::MAX, i128::MIN);
    let mut pad_cols = false;
    for g in target_sites {
        let mult = product_of(&g.site.loops, loops, roles, &[Role::Tap])
            .ok_or_else(|| too_large("tap multiplicity overflows"))?;
        taps = taps.checked_add(mult).ok_or_else(|| too_large("tap count overflows"))?;
        tx_weighted += mult as f64 * tx_per_access(&g.rc, launch, dev.warp_size, seg);
        let (rlo, rhi) = offset_interval(&g.rc.row, loops, roles);
        let (clo, chi) = offset_interval(&g.rc.col, loops, roles);
        off = (off.0.min(rlo), off.1.max(rhi), off.2.min(clo), off.3.max(chi));
        let (rlo, rhi) = coord_interval(&g.rc.row, launch, loops, roles);
        let (clo, chi) = coord_interval(&g.rc.col, launch, loops, roles);
        region = (region.0.min(rlo), region.1.max(rhi), region.2.min(clo), region.3.max(chi));
        if g.rc.row.wi_coeff(0) != 0 {
            // Warp lanes traverse the staged tile along the slow
            // dimension (transposed access): classic +1 column pad to
            // dodge bank conflicts.
            pad_cols = true;
        }
    }
    if taps == 0 || taps > u32::MAX as u64 {
        return Err(too_large("tap count out of range"));
    }
    let tx_per_target_access = tx_weighted / taps as f64;
    let bound_i32 = |v: i128, what: &str| -> EResult<i32> {
        i32::try_from(v).map_err(|_| too_large(what))
    };
    let offset_bounds = (
        bound_i32(off.0, "row tap offset out of range")?,
        bound_i32(off.1, "row tap offset out of range")?,
        bound_i32(off.2, "column tap offset out of range")?,
        bound_i32(off.3, "column tap offset out of range")?,
    );
    let dim_of = |lo: i128, hi: i128, what: &str| -> EResult<u64> {
        let d = hi - lo + 1;
        if d < 1 || d > u32::MAX as i128 {
            Err(too_large(what))
        } else {
            Ok(d as u64)
        }
    };
    let region_rows = dim_of(region.0, region.1, "staged-region rows out of range")?;
    let region_cols_unpadded = dim_of(region.2, region.3, "staged-region columns out of range")?;
    let region_cols = region_cols_unpadded + pad_cols as u64;

    // Degree of reuse: accesses per round over distinct staged elements.
    let accesses_per_round = launch.wg.size() as f64 * taps as f64 * inner_iters as f64;
    let reuse = accesses_per_round / (region_rows as f64 * region_cols_unpadded as f64);

    // Contextual accesses & computation, bucketed body/epilogue.
    let mut coal = [0u64; 2]; // [ilb, ep]
    let mut uncoal = [0u64; 2];
    let mut comp = [0u64; 2];
    let in_body = |loop_ids: &[u32]| {
        loop_ids.iter().any(|&id| matches!(roles[id as usize], Role::Inner | Role::Tap))
    };
    for g in globals {
        if g.site.array == walker_target(target_sites) {
            continue;
        }
        let mult = product_of(&g.site.loops, loops, roles, &[Role::Tap, Role::Other])
            .ok_or_else(|| too_large("context access count overflows"))?;
        let body = in_body(&g.site.loops) || (!g.site.is_store && inner_iters == 1);
        let coalesced = tx_per_access(&g.rc, launch, dev.warp_size, seg) <= 1.0;
        let slot = if body { 0 } else { 1 };
        let bucket = if coalesced { &mut coal } else { &mut uncoal };
        bucket[slot] = bucket[slot]
            .checked_add(mult)
            .ok_or_else(|| too_large("context access count overflows"))?;
    }
    for c in &walker.comps {
        let mult = product_of(&c.loops, loops, roles, &[Role::Tap, Role::Other])
            .ok_or_else(|| too_large("computation count overflows"))?;
        let slot = if in_body(&c.loops) { 0 } else { 1 };
        let added = mult.checked_mul(c.ops as u64).and_then(|v| comp[slot].checked_add(v));
        comp[slot] = added.ok_or_else(|| too_large("computation count overflows"))?;
    }
    let as_u32 = |v: u64, what: &str| -> EResult<u32> {
        u32::try_from(v).map_err(|_| too_large(what))
    };

    let base_regs = REG_BASE + 2 * walker.decls + (taps as u32) / 4;
    Ok(DescriptorBuilder {
        name: kernel.name.clone(),
        taps: taps as u32,
        inner_iters,
        comp_ilb: as_u32(comp[0], "inner-loop computation out of range")?,
        comp_ep: as_u32(comp[1], "epilogue computation out of range")?,
        coal_ilb: as_u32(coal[0], "context access count out of range")?,
        coal_ep: as_u32(coal[1], "context access count out of range")?,
        uncoal_ilb: as_u32(uncoal[0], "context access count out of range")?,
        uncoal_ep: as_u32(uncoal[1], "context access count out of range")?,
        tx_per_target_access,
        region_rows,
        region_cols,
        reuse,
        offset_bounds,
        base_regs,
        opt_extra_regs: OPT_EXTRA_REGS,
        launch: *launch,
        wus_per_wi,
    }
    .build(dev))
}

fn walker_target(target_sites: &[&GlobalSite]) -> &str {
    &target_sites[0].site.array
}
