//! Analytic GPU timing model (the ground-truth "testbed").
//!
//! The paper measures kernels on a Tesla M2090; we model that measurement
//! with a Hong/Kim-style (ISCA'09) analytic pipeline: per work-unit round
//! we count warp instructions, DRAM transactions, and shared-memory
//! accesses for each variant, then bound execution by the three classic
//! regimes —
//!
//!   issue    : every resident warp's instructions through the issue port,
//!   bandwidth: every resident warp's DRAM transactions through the SM's
//!              fair share of memory bandwidth,
//!   latency  : one warp's dependence-limited critical path (exposed when
//!              occupancy is too low to hide DRAM latency — exactly the
//!              "drop in parallelism" harm of paper §3).
//!
//! cycles/round/SM = max(issue, bandwidth, latency); kernel time scales by
//! rounds per workitem and block waves per SM. Waves are counted
//! exactly: full waves at the occupancy-limited residency plus at most
//! one residual wave at the leftover blocks' own (lower) residency, so a
//! grid that overfills the device by one block pays one extra block's
//! time, not a whole extra wave. `Bound` attribution is deterministic on
//! exact ties (Bandwidth > Issue > Latency, see `classify_bound`).
//!
//! ## Baseline cache model
//!
//! Fermi's L1/L2 partially absorb the baseline target-array traffic; the
//! interesting tension the paper studies exists precisely because caches
//! capture *some* reuse but thrash where explicit staging would not.
//!
//! IMPORTANT DESIGN INVARIANT: the hit rate is a function of quantities
//! that are *visible in the 18 model features* (non-coalescing degree,
//! reuse, staged-region size, workgroup size, registers). The paper's
//! premise is that its features determine the optimization's benefit; if
//! the simulator consulted hidden state, two instances with identical
//! features could carry different labels and no model could learn the
//! map. Classification, with `h = h_max * min(1, capacity/working_set)`:
//!
//! * Scattered lanes (tx/access > 1): one 128 B line per distinct row
//!   must stay resident per warp; working set = warps x tx x line,
//!   capacity = L1 only, h_max 31/32 — the capacity-thrashing
//!   transpose/row-reduction case the optimization exists for.
//! * Coalesced with reuse (tx <= 1, reuse > 1): the staged-region
//!   footprint is revisited through the cache; working set = region x
//!   resident blocks, capacity = L1+L2, h_max 0.65.
//! * Coalesced streaming (reuse <= 1): compulsory misses only, hit 0.

use crate::gpu::occupancy::{occupancy, BlockUsage, Limiter, Occupancy};
use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;

/// Which kernel variant to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Original kernel: target accesses go to DRAM through the caches.
    Baseline,
    /// Local-memory optimized: region staged cooperatively, target
    /// accesses served from shared memory, two barriers per round.
    Optimized,
}

/// Per-warp per-work-unit-round instruction/transaction counts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WarpProfile {
    pub comp_insts: f64,
    pub gmem_insts: f64,
    /// DRAM transactions after the cache model.
    pub gmem_tx: f64,
    pub smem_insts: f64,
    pub barriers: f64,
    /// Average latency of a global-memory instruction (cycles), cache-
    /// aware for the baseline target accesses.
    pub avg_gmem_latency: f64,
}

/// Binding regime of the simulated kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    Issue,
    Bandwidth,
    Latency,
    Infeasible,
}

#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    /// End-to-end kernel time in seconds (infinite if infeasible).
    pub time_s: f64,
    pub cycles_per_round: f64,
    pub occupancy: Occupancy,
    pub bound: Bound,
    pub profile: WarpProfile,
    /// Baseline target-access cache hit rate (0 for optimized variant).
    pub cache_hit: f64,
}

impl SimResult {
    pub fn feasible(&self) -> bool {
        self.time_s.is_finite()
    }
}

/// Memory-level parallelism proxy: independent loads a warp has in flight
/// (stencil taps are mutually independent, as are contextual accesses).
fn mlp(d: &KernelDescriptor) -> f64 {
    (d.taps as f64).clamp(1.0, 6.0)
}

/// Baseline cache hit rate for target-array accesses (see module docs).
/// Depends only on feature-visible quantities plus occupancy (itself a
/// function of features: workgroup size, registers, shared memory).
pub fn baseline_cache_hit(d: &KernelDescriptor, dev: &DeviceSpec, occ: &Occupancy) -> f64 {
    let l1 = dev.l1_bytes as f64;
    let l1l2 = (dev.l1_bytes + dev.l2_bytes_per_sm) as f64;
    let line = dev.transaction_bytes as f64;
    if d.tx_per_target_access > 1.5 {
        // Scattered lanes: one line per distinct row per warp.
        let ws = occ.warps_per_sm.max(1) as f64 * d.tx_per_target_access * line;
        (31.0 / 32.0) * (l1 / ws).min(1.0)
    } else if d.reuse > 1.0 {
        // Coalesced with reuse: region revisited through L1+L2.
        let ws = d.region_bytes() as f64 * occ.blocks_per_sm.max(1) as f64;
        0.65 * (l1l2 / ws).min(1.0)
    } else {
        // Coalesced streaming: nothing to cache.
        0.0
    }
}

fn profile_for(
    d: &KernelDescriptor,
    dev: &DeviceSpec,
    v: Variant,
    cache_hit: f64,
) -> WarpProfile {
    let ctx_insts = d.ctx_insts_per_round();
    let ctx_tx = d.ctx_tx_per_round();
    let target = d.target_insts_per_round();
    match v {
        Variant::Baseline => {
            let target_tx = target * d.tx_per_target_access * (1.0 - cache_hit);
            let gmem_insts = target + ctx_insts;
            // Target loads hit L1/L2 with `cache_hit`, else DRAM; ctx
            // loads are modelled as DRAM-latency streams.
            let avg_lat = if gmem_insts > 0.0 {
                let t_lat = cache_hit * dev.cache_hit_latency
                    + (1.0 - cache_hit) * dev.mem_latency;
                (target * t_lat + ctx_insts * dev.mem_latency) / gmem_insts
            } else {
                dev.mem_latency
            };
            WarpProfile {
                comp_insts: d.comp_insts_per_round(),
                gmem_insts,
                gmem_tx: target_tx + ctx_tx,
                smem_insts: 0.0,
                barriers: 0.0,
                avg_gmem_latency: avg_lat,
            }
        }
        Variant::Optimized => {
            // Cooperative copy: fully coalesced row segments, cyclically
            // distributed over the workgroup's warps (paper §2).
            let copy_tx_wg = d.copy_transactions(dev);
            let copy_per_warp = copy_tx_wg / d.warps_per_wg(dev) as f64;
            WarpProfile {
                // Address arithmetic of the copy loop rides the ALUs.
                comp_insts: d.comp_insts_per_round() + copy_per_warp,
                gmem_insts: ctx_insts + copy_per_warp,
                gmem_tx: ctx_tx + copy_per_warp,
                // Taps read from shared memory + the staging stores.
                smem_insts: target + copy_per_warp,
                barriers: 2.0,
                avg_gmem_latency: dev.mem_latency,
            }
        }
    }
}

pub fn block_usage(d: &KernelDescriptor, v: Variant) -> BlockUsage {
    match v {
        Variant::Baseline => BlockUsage {
            threads_per_block: d.launch.wg.size(),
            regs_per_thread: d.base_regs,
            shared_bytes_per_block: 0,
        },
        Variant::Optimized => BlockUsage {
            threads_per_block: d.launch.wg.size(),
            regs_per_thread: d.base_regs + d.opt_extra_regs,
            shared_bytes_per_block: d.region_bytes().min(u32::MAX as u64) as u32,
        },
    }
}

pub fn simulate(d: &KernelDescriptor, dev: &DeviceSpec, v: Variant) -> SimResult {
    let usage = block_usage(d, v);
    let occ = occupancy(dev, &usage);
    let cache_hit = match v {
        Variant::Baseline => baseline_cache_hit(d, dev, &occ),
        Variant::Optimized => 0.0,
    };
    let profile = profile_for(d, dev, v, cache_hit);

    if occ.limiter == Limiter::Infeasible {
        return SimResult {
            time_s: f64::INFINITY,
            cycles_per_round: f64::INFINITY,
            occupancy: occ,
            bound: Bound::Infeasible,
            profile,
            cache_hit,
        };
    }

    let total_blocks = d.launch.total_groups().max(1);
    let warps_per_block = dev.warps_for_threads(d.launch.wg.size()) as f64;

    // Barrier cost: fixed pipeline drain + reconvergence over the block's
    // warps, paid once per barrier per round.
    let barrier_cycles =
        profile.barriers * (dev.barrier_base_cost + warps_per_block);

    // Per-warp issue work for one round.
    let issue_per_warp = profile.comp_insts
        + profile.gmem_insts
        + profile.smem_insts
        + barrier_cycles;

    // One warp's dependence-limited stall time.
    let stall = profile.gmem_insts * profile.avg_gmem_latency / mlp(d)
        + profile.smem_insts * dev.smem_latency / 4.0;

    // Per-wave cycles for a given residency: issue and bandwidth scale
    // with the resident warps, the latency floor does not.
    let cycles_for = |resident_blocks: u32| -> f64 {
        let w = resident_blocks as f64 * warps_per_block;
        let issue = w * issue_per_warp;
        let bandwidth = w * profile.gmem_tx * dev.tx_departure_cycles();
        let latency = issue_per_warp + stall;
        issue.max(bandwidth).max(latency)
    };

    // Wave accounting: the launch fills the device with
    // `blocks_per_sm * num_sms` blocks per full wave; whatever remains
    // runs as ONE residual wave at its own (lower) residency instead of
    // being billed as another full wave. A 17-block grid on 16 SMs is a
    // single wave whose busiest SM holds 2 blocks — not 32 blocks of
    // work; a 33-block grid is one full wave plus a 1-block/SM residual,
    // not two full waves. This keeps simulated time monotone
    // non-decreasing in the grid's block count (tested below).
    let per_wave = occ.blocks_per_sm as u64 * dev.num_sms as u64;
    let full_waves = total_blocks / per_wave;
    let residual_blocks = total_blocks - full_waves * per_wave;
    let residual_per_sm =
        residual_blocks.div_ceil(dev.num_sms as u64).min(u32::MAX as u64) as u32;

    // The steady-state residency reported in `cycles_per_round` / `bound`
    // is the full wave's when one exists, else the single partial wave's.
    let steady_blocks = if full_waves > 0 {
        occ.blocks_per_sm
    } else {
        residual_per_sm
    };
    let cycles = cycles_for(steady_blocks);
    let w = steady_blocks as f64 * warps_per_block;
    let bound = classify_bound(
        w * issue_per_warp,
        w * profile.gmem_tx * dev.tx_departure_cycles(),
        issue_per_warp + stall,
    );

    let mut wave_cycles = full_waves as f64 * cycles_for(occ.blocks_per_sm);
    if residual_per_sm > 0 {
        wave_cycles += cycles_for(residual_per_sm);
    }

    let total_cycles = wave_cycles * d.wus_per_wi as f64;
    SimResult {
        time_s: total_cycles / dev.clock_hz,
        cycles_per_round: cycles,
        occupancy: occ,
        bound,
        profile,
        cache_hit,
    }
}

/// Deterministic regime attribution for one wave's cycle count. On exact
/// ties the documented order is Bandwidth > Issue > Latency: when two
/// regimes cost the same, the one earlier in that order is reported, so
/// attribution can never flip between runs (or platforms) on an equal
/// `max`.
fn classify_bound(issue: f64, bandwidth: f64, latency: f64) -> Bound {
    let c = issue.max(bandwidth).max(latency);
    if bandwidth == c {
        Bound::Bandwidth
    } else if issue == c {
        Bound::Issue
    } else {
        Bound::Latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::access::HomePattern;
    use crate::kernelmodel::launch::{GridGeom, Launch, WgGeom};
    use crate::kernelmodel::stencil::StencilPattern;
    use crate::kernelmodel::template::Template;

    fn dev() -> DeviceSpec {
        DeviceSpec::m2090()
    }

    fn descr(home: HomePattern, wg: (u32, u32), n: u32, m: u32) -> KernelDescriptor {
        let launch = Launch::new(
            WgGeom { w: wg.0, h: wg.1 },
            GridGeom { w: 1024, h: 1024 },
        );
        let t = Template {
            home,
            n,
            m,
            stencil: StencilPattern::Rectangular,
            radius: 1,
            ..Template::base()
        };
        t.descriptor(&launch, &dev())
    }

    #[test]
    fn baseline_profile_counts() {
        // cache_hit forced to 0 so raw counts are visible
        let d = descr(HomePattern::YReuseCol, (16, 8), 8, 8);
        let p = profile_for(&d, &dev(), Variant::Baseline, 0.0);
        assert_eq!(p.comp_insts, 10.0 * 64.0 + 10.0);
        assert_eq!(p.smem_insts, 0.0);
        assert_eq!(p.barriers, 0.0);
        // 9 taps * 64 iters target + (1 coal ilb * 64 + 1 coal ep) ctx
        assert_eq!(p.gmem_insts, 576.0 + 65.0);
        assert_eq!(p.gmem_tx, 576.0 + 65.0); // coalesced, no cache help
    }

    #[test]
    fn broadcast_target_mostly_hits_cache() {
        let d = descr(HomePattern::XyReuse, (16, 8), 8, 8);
        let r = simulate(&d, &dev(), Variant::Baseline);
        assert!(r.cache_hit > 0.5, "hit {}", r.cache_hit);
    }

    #[test]
    fn scattered_walk_thrashes_cache_at_high_occupancy() {
        let d = descr(HomePattern::NoReuseRow, (32, 4), 1, 8);
        let r = simulate(&d, &dev(), Variant::Baseline);
        assert!(r.occupancy.warps_per_sm >= 16);
        assert!(r.cache_hit < 0.35, "hit {}", r.cache_hit);
    }

    #[test]
    fn optimized_moves_target_traffic_to_smem() {
        let d = descr(HomePattern::NoReuseRow, (32, 4), 2, 4);
        let base = simulate(&d, &dev(), Variant::Baseline);
        let opt = simulate(&d, &dev(), Variant::Optimized);
        assert!(
            opt.profile.gmem_tx < base.profile.gmem_tx,
            "{} !< {}",
            opt.profile.gmem_tx,
            base.profile.gmem_tx
        );
        assert!(opt.profile.smem_insts > 0.0);
        assert_eq!(opt.profile.barriers, 2.0);
    }

    #[test]
    fn simulate_produces_finite_time() {
        let d = descr(HomePattern::XyReuse, (16, 8), 16, 16);
        let r = simulate(&d, &dev(), Variant::Baseline);
        assert!(r.feasible());
        assert!(r.time_s > 0.0);
        assert!(r.occupancy.warps_per_sm > 0);
    }

    #[test]
    fn oversized_region_is_infeasible() {
        // no_reuse_row with a 512-thread workgroup: region rows = 514.
        let d = descr(HomePattern::NoReuseRow, (32, 16), 8, 8);
        assert!(d.region_bytes() > 48 * 1024);
        let r = simulate(&d, &dev(), Variant::Optimized);
        assert_eq!(r.bound, Bound::Infeasible);
        assert!(!r.feasible());
        // ...but the baseline still runs.
        assert!(simulate(&d, &dev(), Variant::Baseline).feasible());
    }

    #[test]
    fn uncoalesced_baseline_is_bandwidth_bound() {
        let d = descr(HomePattern::NoReuseRow, (32, 4), 1, 8);
        let r = simulate(&d, &dev(), Variant::Baseline);
        assert_eq!(r.bound, Bound::Bandwidth);
    }

    #[test]
    fn coalescing_fix_speeds_up_scattered_pattern() {
        // The §2 motivating case: row-wise walk, fully scattered lanes.
        let d = descr(HomePattern::NoReuseRow, (32, 2), 1, 8);
        let base = simulate(&d, &dev(), Variant::Baseline);
        let opt = simulate(&d, &dev(), Variant::Optimized);
        assert!(opt.feasible());
        assert!(
            base.time_s / opt.time_s > 1.5,
            "expected speedup, got {}",
            base.time_s / opt.time_s
        );
    }

    #[test]
    fn broadcast_pattern_gains_little_or_loses() {
        // xy_reuse hits the cache; staging adds copy + barrier +
        // occupancy cost for little traffic benefit.
        let d = descr(HomePattern::XyReuse, (8, 8), 8, 8);
        let base = simulate(&d, &dev(), Variant::Baseline);
        let opt = simulate(&d, &dev(), Variant::Optimized);
        let speedup = base.time_s / opt.time_s;
        assert!(speedup < 4.0, "speedup {speedup} suspiciously high");
    }

    #[test]
    fn occupancy_drop_can_hurt() {
        // Large staged region -> few resident blocks; with a small
        // workgroup the optimized kernel cannot hide latency anymore.
        let d = descr(HomePattern::XyReuse, (8, 8), 64, 64);
        let base = simulate(&d, &dev(), Variant::Baseline);
        let opt = simulate(&d, &dev(), Variant::Optimized);
        assert!(opt.occupancy.warps_per_sm < base.occupancy.warps_per_sm);
    }

    #[test]
    fn bound_tie_order_is_bandwidth_issue_latency() {
        use super::classify_bound;
        // exact three-way tie -> Bandwidth
        assert_eq!(classify_bound(2.0, 2.0, 2.0), Bound::Bandwidth);
        // issue/bandwidth tie -> Bandwidth
        assert_eq!(classify_bound(3.0, 3.0, 1.0), Bound::Bandwidth);
        // issue/latency tie above bandwidth -> Issue
        assert_eq!(classify_bound(3.0, 1.0, 3.0), Bound::Issue);
        // strict maxima keep their own label
        assert_eq!(classify_bound(5.0, 1.0, 1.0), Bound::Issue);
        assert_eq!(classify_bound(1.0, 5.0, 1.0), Bound::Bandwidth);
        assert_eq!(classify_bound(1.0, 1.0, 5.0), Bound::Latency);
    }

    #[test]
    fn time_is_monotone_in_total_groups() {
        // Fixed per-round work and fixed wus_per_wi (descriptor built
        // directly, so growing the grid does not shrink the per-item
        // rounds): simulated time must be non-decreasing in the block
        // count, including across full-wave boundaries.
        let dev = dev();
        let base = {
            let launch = Launch::new(
                WgGeom { w: 16, h: 8 },
                GridGeom { w: 128, h: 128 },
            );
            Template::base().descriptor(&launch, &dev)
        };
        let mut last = 0.0f64;
        let mut last_groups = 0u64;
        for gh in [128u32, 256, 512, 1024, 2048, 4096] {
            for gw in [128u32, 256] {
                let mut d = base.clone();
                d.launch.grid = GridGeom { w: gw, h: gh };
                let groups = d.launch.total_groups();
                let r = simulate(&d, &dev, Variant::Baseline);
                assert!(r.feasible());
                if groups >= last_groups {
                    assert!(
                        r.time_s >= last * (1.0 - 1e-12),
                        "time dropped from {last} to {} when groups grew \
                         {last_groups} -> {groups}",
                        r.time_s
                    );
                    last = r.time_s;
                    last_groups = groups;
                }
            }
        }
        assert!(last_groups > 0);
    }

    #[test]
    fn residual_wave_is_cheaper_than_a_full_wave() {
        // One block past an exact multiple of the device's concurrent
        // capacity must cost less than a whole extra wave.
        let dev = dev();
        let launch = Launch::new(
            WgGeom { w: 16, h: 8 },
            GridGeom { w: 128, h: 128 },
        );
        let base = Template::base().descriptor(&launch, &dev);
        let occ = occupancy(&dev, &block_usage(&base, Variant::Baseline));
        let per_wave = (occ.blocks_per_sm * dev.num_sms) as u64;
        assert!(per_wave > 1);

        // grid sized to exactly two full waves, in blocks of 128 threads
        let mk = |groups: u64| {
            let mut d = base.clone();
            // wg 16x8 => groups = (gw/16)*(gh/8); encode groups on one axis
            d.launch.grid = GridGeom { w: 16 * groups as u32, h: 8 };
            d
        };
        let exact = simulate(&mk(2 * per_wave), &dev, Variant::Baseline);
        let plus_one = simulate(&mk(2 * per_wave + 1), &dev, Variant::Baseline);
        let three_waves = simulate(&mk(3 * per_wave), &dev, Variant::Baseline);
        assert!(plus_one.time_s > exact.time_s, "extra block must cost time");
        assert!(
            plus_one.time_s < three_waves.time_s,
            "one extra block ({}) must cost less than a full extra wave ({})",
            plus_one.time_s,
            three_waves.time_s
        );
    }

    #[test]
    fn more_rounds_cost_more_time() {
        let launch_small = Launch::new(
            WgGeom { w: 16, h: 8 },
            GridGeom { w: 2048, h: 2048 },
        );
        let launch_big = Launch::new(
            WgGeom { w: 16, h: 8 },
            GridGeom { w: 512, h: 512 },
        );
        let t = Template::base();
        let d1 = t.descriptor(&launch_small, &dev()); // 1 wu/wi
        let d2 = t.descriptor(&launch_big, &dev()); // 16 wus/wi
        let r1 = simulate(&d1, &dev(), Variant::Baseline);
        let r2 = simulate(&d2, &dev(), Variant::Baseline);
        assert!(r1.feasible() && r2.feasible());
        let ratio = r1.time_s / r2.time_s;
        assert!((0.2..5.0).contains(&ratio), "ratio {ratio}");
    }
}
