//! Run one kernel instance with and without the optimization and record
//! the paper's ground-truth quantities: kernel speedup + oracle decision.

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;
use crate::kernelmodel::features::{extract, NUM_FEATURES};
use crate::util::prng::Rng;

use super::timing::{simulate, SimResult, Variant};

/// Speedups are clamped to this range, mirroring the paper's observed
/// 0.03x .. 49.6x spread (infeasible optimized variants clamp low).
pub const SPEEDUP_MIN: f64 = 0.01;
pub const SPEEDUP_MAX: f64 = 100.0;

/// One measured kernel instance: the dataset row.
#[derive(Clone, Debug)]
pub struct SpeedupRecord {
    pub name: String,
    pub features: [f64; NUM_FEATURES],
    /// t_baseline / t_optimized, clamped.
    pub speedup: f64,
    pub baseline_time: f64,
    pub optimized_time: f64,
}

impl SpeedupRecord {
    /// Oracle decision (paper §5.1): apply the optimization iff it wins.
    pub fn beneficial(&self) -> bool {
        self.speedup > 1.0
    }

    /// Regression target used for training: log2(speedup), so the
    /// decision boundary is 0 and slowdowns/speedups are symmetric.
    pub fn target(&self) -> f64 {
        self.speedup.log2()
    }

    /// Flatten to the dataset persistence layout: the feature vector
    /// followed by the measured speedup (`synth::dataset::csv_header`
    /// order). Raw times are not persisted.
    pub fn csv_row(&self) -> Vec<f64> {
        let mut row = Vec::with_capacity(NUM_FEATURES + 1);
        row.extend_from_slice(&self.features);
        row.push(self.speedup);
        row
    }

    /// Rebuild from a persisted row (`csv_row` layout). The raw times
    /// are not stored on disk, so they come back as NaN.
    ///
    /// Row width is validated in every build profile: a short row is a
    /// typed `Err`, never a `copy_from_slice` panic, and an over-long
    /// row (which a `debug_assert` would silently accept in release
    /// builds) is rejected the same way.
    pub fn from_csv_row(name: String, row: &[f64]) -> anyhow::Result<Self> {
        anyhow::ensure!(
            row.len() == NUM_FEATURES + 1,
            "record '{name}': row has {} columns, expected {} (features + speedup)",
            row.len(),
            NUM_FEATURES + 1
        );
        let mut features = [0.0; NUM_FEATURES];
        features.copy_from_slice(&row[..NUM_FEATURES]);
        Ok(SpeedupRecord {
            name,
            features,
            speedup: row[NUM_FEATURES],
            baseline_time: f64::NAN,
            optimized_time: f64::NAN,
        })
    }
}

/// Measurement configuration for the simulated testbed.
#[derive(Clone, Copy, Debug)]
pub struct MeasureConfig {
    /// Multiplicative lognormal measurement jitter (std of ln-ratio).
    /// The paper's timings carry run-to-run noise; 0.0 = deterministic.
    pub noise_sigma: f64,
    pub seed: u64,
}

impl Default for MeasureConfig {
    fn default() -> Self {
        // ~2% run-to-run jitter, typical of wall-clock GPU kernel timing.
        MeasureConfig { noise_sigma: 0.02, seed: 0x7E57BED }
    }
}

impl MeasureConfig {
    pub fn deterministic() -> Self {
        MeasureConfig { noise_sigma: 0.0, seed: 0 }
    }
}

/// "Measure" one kernel instance on the simulated device.
pub fn measure(
    d: &KernelDescriptor,
    dev: &DeviceSpec,
    cfg: &MeasureConfig,
) -> SpeedupRecord {
    let base = simulate(d, dev, Variant::Baseline);
    let opt = simulate(d, dev, Variant::Optimized);
    measure_from(d, &base, &opt, cfg)
}

/// Build the record from precomputed simulations (used by tests/ablation).
pub fn measure_from(
    d: &KernelDescriptor,
    base: &SimResult,
    opt: &SimResult,
    cfg: &MeasureConfig,
) -> SpeedupRecord {
    let mut t_base = base.time_s;
    let mut t_opt = opt.time_s;
    if cfg.noise_sigma > 0.0 {
        // Deterministic per-instance jitter: seed from the feature hash so
        // the same instance always "measures" the same.
        let mut h = 0xcbf29ce484222325u64;
        for b in d.name.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        let mut rng = Rng::new(cfg.seed ^ h);
        t_base *= (cfg.noise_sigma * rng.normal()).exp();
        t_opt *= (cfg.noise_sigma * rng.normal()).exp();
    }
    let speedup = if !t_opt.is_finite() {
        SPEEDUP_MIN
    } else {
        (t_base / t_opt).clamp(SPEEDUP_MIN, SPEEDUP_MAX)
    };
    SpeedupRecord {
        name: d.name.clone(),
        features: extract(d),
        speedup,
        baseline_time: t_base,
        optimized_time: t_opt,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::access::HomePattern;
    use crate::kernelmodel::launch::{GridGeom, Launch, WgGeom};
    use crate::kernelmodel::template::Template;

    fn record(home: HomePattern, wg: (u32, u32), n: u32, m: u32) -> SpeedupRecord {
        let dev = DeviceSpec::m2090();
        let launch = Launch::new(
            WgGeom { w: wg.0, h: wg.1 },
            GridGeom { w: 1024, h: 1024 },
        );
        let t = Template { home, n, m, ..Template::base() };
        let d = t.descriptor(&launch, &dev);
        measure(&d, &dev, &MeasureConfig::deterministic())
    }

    #[test]
    fn scattered_pattern_is_beneficial() {
        let r = record(HomePattern::NoReuseRow, (32, 2), 1, 8);
        assert!(r.beneficial(), "speedup {}", r.speedup);
        assert!(r.target() > 0.0);
    }

    #[test]
    fn infeasible_region_clamps_to_min() {
        // 512-thread workgroup, each owning a row: region >> 48 KB.
        let r = record(HomePattern::NoReuseRow, (32, 16), 8, 8);
        assert_eq!(r.speedup, SPEEDUP_MIN);
        assert!(!r.beneficial());
    }

    #[test]
    fn speedup_within_clamp_range() {
        for home in HomePattern::ALL {
            let n = home.n_values()[1];
            let m = home.m_values()[1];
            let r = record(home, (16, 8), n, m);
            assert!((SPEEDUP_MIN..=SPEEDUP_MAX).contains(&r.speedup), "{home}");
        }
    }

    #[test]
    fn noise_is_deterministic_per_instance() {
        let dev = DeviceSpec::m2090();
        let launch = Launch::new(
            WgGeom { w: 16, h: 8 },
            GridGeom { w: 1024, h: 1024 },
        );
        let d = Template::base().descriptor(&launch, &dev);
        let cfg = MeasureConfig::default();
        let a = measure(&d, &dev, &cfg);
        let b = measure(&d, &dev, &cfg);
        assert_eq!(a.speedup, b.speedup);
        // and differs from the noiseless measurement (with high prob.)
        let c = measure(&d, &dev, &MeasureConfig::deterministic());
        assert_ne!(a.speedup, c.speedup);
    }

    #[test]
    fn csv_row_roundtrips() {
        let r = record(HomePattern::NoReuseRow, (32, 2), 1, 8);
        let row = r.csv_row();
        assert_eq!(row.len(), crate::kernelmodel::features::NUM_FEATURES + 1);
        let back = SpeedupRecord::from_csv_row("x".into(), &row).unwrap();
        assert_eq!(back.features, r.features);
        assert_eq!(back.speedup, r.speedup);
        assert!(back.baseline_time.is_nan());
    }

    #[test]
    fn malformed_rows_are_errors_not_panics() {
        // Short row: would have been a copy_from_slice panic in release
        // builds under the old debug_assert-only check.
        let short = vec![1.0; crate::kernelmodel::features::NUM_FEATURES - 3];
        let err = SpeedupRecord::from_csv_row("short".into(), &short).unwrap_err();
        assert!(format!("{err:#}").contains("expected"), "{err:#}");
        // Over-long row: silently truncating it would mis-parse the
        // speedup column; it must be rejected too.
        let long = vec![1.0; crate::kernelmodel::features::NUM_FEATURES + 5];
        assert!(SpeedupRecord::from_csv_row("long".into(), &long).is_err());
        // Empty row.
        assert!(SpeedupRecord::from_csv_row("empty".into(), &[]).is_err());
    }

    #[test]
    fn target_is_log2() {
        let r = record(HomePattern::NoReuseRow, (32, 2), 1, 8);
        assert!((r.target() - r.speedup.log2()).abs() < 1e-12);
    }
}
