//! Run one kernel instance with and without the optimization and record
//! the paper's ground-truth quantities: kernel speedup + oracle decision.

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;
use crate::kernelmodel::features::{extract, NUM_FEATURES};
use crate::util::prng::Rng;

use super::timing::{simulate, SimResult, Variant};

/// Speedups are clamped to this range, mirroring the paper's observed
/// 0.03x .. 49.6x spread (infeasible optimized variants clamp low).
pub const SPEEDUP_MIN: f64 = 0.01;
pub const SPEEDUP_MAX: f64 = 100.0;

/// Dataset schema version. `V1` is the original single-label layout
/// (18 features + speedup); `V2` adds the joint argmax-workgroup label
/// (18 features + speedup + wg_w + wg_h). Persisted as a `# schema=v2`
/// metadata line; files without the stamp are v1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schema {
    V1,
    V2,
}

impl Schema {
    pub fn as_str(&self) -> &'static str {
        match self {
            Schema::V1 => "v1",
            Schema::V2 => "v2",
        }
    }

    /// CSV columns a row of this schema carries.
    pub fn columns(&self) -> usize {
        match self {
            Schema::V1 => NUM_FEATURES + 1,
            Schema::V2 => NUM_FEATURES + 3,
        }
    }

    /// Model outputs a forest trained on this schema produces
    /// (v1: log2 speedup; v2: + log2 wg_w + log2 wg_h).
    pub fn outputs(&self) -> usize {
        match self {
            Schema::V1 => 1,
            Schema::V2 => 3,
        }
    }
}

impl std::fmt::Display for Schema {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Schema {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "v1" => Ok(Schema::V1),
            "v2" => Ok(Schema::V2),
            other => Err(format!("unknown dataset schema {other:?} (v1|v2)")),
        }
    }
}

/// One measured kernel instance: the dataset row.
#[derive(Clone, Debug)]
pub struct SpeedupRecord {
    pub name: String,
    pub features: [f64; NUM_FEATURES],
    /// t_baseline / t_optimized, clamped.
    pub speedup: f64,
    pub baseline_time: f64,
    pub optimized_time: f64,
}

impl SpeedupRecord {
    /// Oracle decision (paper §5.1): apply the optimization iff it wins.
    pub fn beneficial(&self) -> bool {
        self.speedup > 1.0
    }

    /// Regression target used for training: log2(speedup), so the
    /// decision boundary is 0 and slowdowns/speedups are symmetric.
    pub fn target(&self) -> f64 {
        self.speedup.log2()
    }

    /// Flatten to the dataset persistence layout: the feature vector
    /// followed by the measured speedup (`synth::dataset::csv_header`
    /// order). Raw times are not persisted.
    pub fn csv_row(&self) -> Vec<f64> {
        let mut row = Vec::with_capacity(NUM_FEATURES + 1);
        row.extend_from_slice(&self.features);
        row.push(self.speedup);
        row
    }

    /// Rebuild from a persisted row (`csv_row` layout). The raw times
    /// are not stored on disk, so they come back as NaN.
    ///
    /// Row width is validated in every build profile: a short row is a
    /// typed `Err`, never a `copy_from_slice` panic, and an over-long
    /// row (which a `debug_assert` would silently accept in release
    /// builds) is rejected the same way.
    pub fn from_csv_row(name: String, row: &[f64]) -> anyhow::Result<Self> {
        anyhow::ensure!(
            row.len() == NUM_FEATURES + 1,
            "record '{name}': row has {} columns, expected {} (features + speedup)",
            row.len(),
            NUM_FEATURES + 1
        );
        let mut features = [0.0; NUM_FEATURES];
        features.copy_from_slice(&row[..NUM_FEATURES]);
        Ok(SpeedupRecord {
            name,
            features,
            speedup: row[NUM_FEATURES],
            baseline_time: f64::NAN,
            optimized_time: f64::NAN,
        })
    }
}

/// The schema-versioned dataset record: a measured instance plus the
/// joint tuning label. v2 records carry the argmax-workgroup shape of
/// the kernel the instance came from (derived from the launch sweep at
/// generation time, `synth::sweep::argmax_wg`); records up-converted
/// from v1 data carry `None` — the 18-feature vector and speedup stay
/// intact either way.
#[derive(Clone, Debug)]
pub struct TuneRecord {
    pub base: SpeedupRecord,
    /// (w, h) of the fastest measured launch for this instance's
    /// kernel; `None` for records up-converted from single-label data.
    pub best_wg: Option<(u32, u32)>,
}

impl TuneRecord {
    /// Typed up-conversion from a single-label (v1) record: the joint
    /// label is absent, never fabricated.
    pub fn from_v1(base: SpeedupRecord) -> Self {
        TuneRecord { base, best_wg: None }
    }

    /// Typed down-conversion to the single-label (v1) record; the joint
    /// label is dropped.
    pub fn into_v1(self) -> SpeedupRecord {
        self.base
    }

    /// The richest schema this record can be written under losslessly.
    pub fn schema(&self) -> Schema {
        if self.best_wg.is_some() { Schema::V2 } else { Schema::V1 }
    }

    /// Regression targets for the workgroup outputs: (log2 w, log2 h).
    pub fn wg_targets(&self) -> Option<(f64, f64)> {
        self.best_wg
            .map(|(w, h)| ((w as f64).log2(), (h as f64).log2()))
    }

    /// Flatten under `schema`. v1 drops the label; v2 writes an
    /// unlabeled record as the `0,0` sentinel (round-trips back to
    /// `None`).
    pub fn csv_row(&self, schema: Schema) -> Vec<f64> {
        let mut row = self.base.csv_row();
        if schema == Schema::V2 {
            let (w, h) = self.best_wg.unwrap_or((0, 0));
            row.push(w as f64);
            row.push(h as f64);
        }
        row
    }

    /// Rebuild from a persisted row of the given schema. The workgroup
    /// label must be the `0,0` sentinel or a valid launch shape (powers
    /// of two, <= 1024 workitems); anything else is a typed error, not
    /// a silently-misparsed label.
    pub fn from_csv_row(
        schema: Schema,
        name: String,
        row: &[f64],
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            row.len() == schema.columns(),
            "record '{name}': row has {} columns, expected {} for schema {schema}",
            row.len(),
            schema.columns()
        );
        let base = SpeedupRecord::from_csv_row(name, &row[..NUM_FEATURES + 1])?;
        let best_wg = match schema {
            Schema::V1 => None,
            Schema::V2 => {
                let (fw, fh) = (row[NUM_FEATURES + 1], row[NUM_FEATURES + 2]);
                let ok = |x: f64| x >= 0.0 && x.fract() == 0.0 && x <= 1024.0;
                anyhow::ensure!(
                    ok(fw) && ok(fh),
                    "record '{}': workgroup label ({fw}, {fh}) is not a \
                     whole non-negative shape",
                    base.name
                );
                let (w, h) = (fw as u32, fh as u32);
                if (w, h) == (0, 0) {
                    None
                } else {
                    anyhow::ensure!(
                        w.is_power_of_two()
                            && h.is_power_of_two()
                            && w as u64 * h as u64 <= 1024,
                        "record '{}': workgroup label {w}x{h} is not a \
                         valid power-of-two launch shape",
                        base.name
                    );
                    Some((w, h))
                }
            }
        };
        Ok(TuneRecord { base, best_wg })
    }
}

/// Measurement configuration for the simulated testbed.
#[derive(Clone, Copy, Debug)]
pub struct MeasureConfig {
    /// Multiplicative lognormal measurement jitter (std of ln-ratio).
    /// The paper's timings carry run-to-run noise; 0.0 = deterministic.
    pub noise_sigma: f64,
    pub seed: u64,
}

impl Default for MeasureConfig {
    fn default() -> Self {
        // ~2% run-to-run jitter, typical of wall-clock GPU kernel timing.
        MeasureConfig { noise_sigma: 0.02, seed: 0x7E57BED }
    }
}

impl MeasureConfig {
    pub fn deterministic() -> Self {
        MeasureConfig { noise_sigma: 0.0, seed: 0 }
    }
}

/// "Measure" one kernel instance on the simulated device.
pub fn measure(
    d: &KernelDescriptor,
    dev: &DeviceSpec,
    cfg: &MeasureConfig,
) -> SpeedupRecord {
    let base = simulate(d, dev, Variant::Baseline);
    let opt = simulate(d, dev, Variant::Optimized);
    measure_from(d, &base, &opt, cfg)
}

/// Build the record from precomputed simulations (used by tests/ablation).
pub fn measure_from(
    d: &KernelDescriptor,
    base: &SimResult,
    opt: &SimResult,
    cfg: &MeasureConfig,
) -> SpeedupRecord {
    let mut t_base = base.time_s;
    let mut t_opt = opt.time_s;
    if cfg.noise_sigma > 0.0 {
        // Deterministic per-instance jitter: seed from the feature hash so
        // the same instance always "measures" the same.
        let mut h = 0xcbf29ce484222325u64;
        for b in d.name.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        let mut rng = Rng::new(cfg.seed ^ h);
        t_base *= (cfg.noise_sigma * rng.normal()).exp();
        t_opt *= (cfg.noise_sigma * rng.normal()).exp();
    }
    let speedup = if !t_opt.is_finite() {
        SPEEDUP_MIN
    } else {
        (t_base / t_opt).clamp(SPEEDUP_MIN, SPEEDUP_MAX)
    };
    SpeedupRecord {
        name: d.name.clone(),
        features: extract(d),
        speedup,
        baseline_time: t_base,
        optimized_time: t_opt,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::access::HomePattern;
    use crate::kernelmodel::launch::{GridGeom, Launch, WgGeom};
    use crate::kernelmodel::template::Template;

    fn record(home: HomePattern, wg: (u32, u32), n: u32, m: u32) -> SpeedupRecord {
        let dev = DeviceSpec::m2090();
        let launch = Launch::new(
            WgGeom { w: wg.0, h: wg.1 },
            GridGeom { w: 1024, h: 1024 },
        );
        let t = Template { home, n, m, ..Template::base() };
        let d = t.descriptor(&launch, &dev);
        measure(&d, &dev, &MeasureConfig::deterministic())
    }

    #[test]
    fn scattered_pattern_is_beneficial() {
        let r = record(HomePattern::NoReuseRow, (32, 2), 1, 8);
        assert!(r.beneficial(), "speedup {}", r.speedup);
        assert!(r.target() > 0.0);
    }

    #[test]
    fn infeasible_region_clamps_to_min() {
        // 512-thread workgroup, each owning a row: region >> 48 KB.
        let r = record(HomePattern::NoReuseRow, (32, 16), 8, 8);
        assert_eq!(r.speedup, SPEEDUP_MIN);
        assert!(!r.beneficial());
    }

    #[test]
    fn speedup_within_clamp_range() {
        for home in HomePattern::ALL {
            let n = home.n_values()[1];
            let m = home.m_values()[1];
            let r = record(home, (16, 8), n, m);
            assert!((SPEEDUP_MIN..=SPEEDUP_MAX).contains(&r.speedup), "{home}");
        }
    }

    #[test]
    fn noise_is_deterministic_per_instance() {
        let dev = DeviceSpec::m2090();
        let launch = Launch::new(
            WgGeom { w: 16, h: 8 },
            GridGeom { w: 1024, h: 1024 },
        );
        let d = Template::base().descriptor(&launch, &dev);
        let cfg = MeasureConfig::default();
        let a = measure(&d, &dev, &cfg);
        let b = measure(&d, &dev, &cfg);
        assert_eq!(a.speedup, b.speedup);
        // and differs from the noiseless measurement (with high prob.)
        let c = measure(&d, &dev, &MeasureConfig::deterministic());
        assert_ne!(a.speedup, c.speedup);
    }

    #[test]
    fn csv_row_roundtrips() {
        let r = record(HomePattern::NoReuseRow, (32, 2), 1, 8);
        let row = r.csv_row();
        assert_eq!(row.len(), crate::kernelmodel::features::NUM_FEATURES + 1);
        let back = SpeedupRecord::from_csv_row("x".into(), &row).unwrap();
        assert_eq!(back.features, r.features);
        assert_eq!(back.speedup, r.speedup);
        assert!(back.baseline_time.is_nan());
    }

    #[test]
    fn malformed_rows_are_errors_not_panics() {
        // Short row: would have been a copy_from_slice panic in release
        // builds under the old debug_assert-only check.
        let short = vec![1.0; crate::kernelmodel::features::NUM_FEATURES - 3];
        let err = SpeedupRecord::from_csv_row("short".into(), &short).unwrap_err();
        assert!(format!("{err:#}").contains("expected"), "{err:#}");
        // Over-long row: silently truncating it would mis-parse the
        // speedup column; it must be rejected too.
        let long = vec![1.0; crate::kernelmodel::features::NUM_FEATURES + 5];
        assert!(SpeedupRecord::from_csv_row("long".into(), &long).is_err());
        // Empty row.
        assert!(SpeedupRecord::from_csv_row("empty".into(), &[]).is_err());
    }

    #[test]
    fn target_is_log2() {
        let r = record(HomePattern::NoReuseRow, (32, 2), 1, 8);
        assert!((r.target() - r.speedup.log2()).abs() < 1e-12);
    }

    #[test]
    fn schema_parse_and_columns() {
        assert_eq!("v1".parse::<Schema>().unwrap(), Schema::V1);
        assert_eq!("v2".parse::<Schema>().unwrap(), Schema::V2);
        assert!("v3".parse::<Schema>().is_err());
        assert_eq!(Schema::V1.columns(), NUM_FEATURES + 1);
        assert_eq!(Schema::V2.columns(), NUM_FEATURES + 3);
        assert_eq!(Schema::V1.outputs(), 1);
        assert_eq!(Schema::V2.outputs(), 3);
        assert_eq!(Schema::V2.to_string(), "v2");
    }

    #[test]
    fn tune_record_roundtrips_both_schemas() {
        let base = record(HomePattern::NoReuseRow, (32, 2), 1, 8);
        let rec = TuneRecord { base: base.clone(), best_wg: Some((16, 8)) };
        assert_eq!(rec.schema(), Schema::V2);
        assert_eq!(rec.wg_targets(), Some((4.0, 3.0)));

        let row = rec.csv_row(Schema::V2);
        assert_eq!(row.len(), NUM_FEATURES + 3);
        let back = TuneRecord::from_csv_row(Schema::V2, "x".into(), &row).unwrap();
        assert_eq!(back.best_wg, Some((16, 8)));
        assert_eq!(back.base.features, base.features);

        // v1 row drops the label; reading it back up-converts to None
        let row1 = rec.csv_row(Schema::V1);
        assert_eq!(row1.len(), NUM_FEATURES + 1);
        let back1 = TuneRecord::from_csv_row(Schema::V1, "x".into(), &row1).unwrap();
        assert_eq!(back1.best_wg, None);
        assert_eq!(back1.schema(), Schema::V1);
    }

    #[test]
    fn up_down_conversion_preserves_the_base_record() {
        let base = record(HomePattern::NoReuseRow, (32, 2), 1, 8);
        let up = TuneRecord::from_v1(base.clone());
        assert_eq!(up.best_wg, None);
        // unlabeled v2 row carries the 0,0 sentinel and round-trips
        let row = up.csv_row(Schema::V2);
        assert_eq!(&row[NUM_FEATURES + 1..], &[0.0, 0.0]);
        let back = TuneRecord::from_csv_row(Schema::V2, "x".into(), &row).unwrap();
        assert_eq!(back.best_wg, None);
        let down = back.into_v1();
        assert_eq!(down.features, base.features);
        assert_eq!(down.speedup, base.speedup);
    }

    #[test]
    fn invalid_wg_labels_are_typed_errors() {
        let base = record(HomePattern::NoReuseRow, (32, 2), 1, 8);
        let rec = TuneRecord::from_v1(base);
        let mut row = rec.csv_row(Schema::V2);
        // non-power-of-two shape
        row[NUM_FEATURES + 1] = 3.0;
        row[NUM_FEATURES + 2] = 4.0;
        assert!(TuneRecord::from_csv_row(Schema::V2, "x".into(), &row).is_err());
        // over-large workgroup
        row[NUM_FEATURES + 1] = 64.0;
        row[NUM_FEATURES + 2] = 64.0;
        assert!(TuneRecord::from_csv_row(Schema::V2, "x".into(), &row).is_err());
        // fractional label
        row[NUM_FEATURES + 1] = 1.5;
        row[NUM_FEATURES + 2] = 2.0;
        assert!(TuneRecord::from_csv_row(Schema::V2, "x".into(), &row).is_err());
        // wrong width for the schema
        assert!(
            TuneRecord::from_csv_row(Schema::V2, "x".into(), &row[..NUM_FEATURES + 1])
                .is_err()
        );
    }
}
