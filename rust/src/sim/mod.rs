//! The simulated testbed: analytic timing model + instance measurement.
pub mod exec;
pub mod timing;
