//! Integration tests over the runtime + coordinator: PJRT artifacts,
//! the batched service, and failure injection. These skip (with a
//! message) when artifacts/ has not been built.

use std::path::PathBuf;
use std::sync::Arc;

use lmtuner::coordinator::service::{Service, ServiceConfig};
use lmtuner::coordinator::train::{self, TrainConfig};
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::kernelmodel::features::NUM_FEATURES;
use lmtuner::runtime::forest_exec::ForestExecutor;
use lmtuner::runtime::pjrt::Engine;
use lmtuner::util::prng::Rng;

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn trained_model_serves_identically_native_and_pjrt() {
    let Some(dir) = artifacts() else { return };
    let dev = DeviceSpec::m2090();
    let cfg = TrainConfig { scale: 0.03, configs_per_kernel: 6, ..Default::default() };
    let out = train::run(&dev, &cfg);
    let engine = Engine::new(&dir).unwrap();
    let enc = train::encode_for_serving(&out.forest, &engine.manifest);
    let exec = ForestExecutor::new(&engine, &enc).unwrap();

    let rows: Vec<Vec<f64>> = out
        .records
        .iter()
        .take(300)
        .map(|r| r.features.to_vec())
        .collect();
    let pjrt = exec.predict(&rows).unwrap();
    let mut graded = 0;
    let mut agree = 0;
    for (row, p) in rows.iter().zip(&pjrt) {
        let native = enc.predict(row);
        assert!((native - p).abs() < 1e-4, "{native} vs {p}");
        let full = out.forest.predict(row);
        if full.abs() > 0.1 {
            graded += 1;
            agree += ((full > 0.0) == (*p > 0.0)) as usize;
        }
    }
    assert!(agree as f64 / graded.max(1) as f64 > 0.95, "{agree}/{graded}");
}

#[test]
fn service_survives_bursts_and_reports_backpressure() {
    let Some(dir) = artifacts() else { return };
    let dev = DeviceSpec::m2090();
    let cfg = TrainConfig { scale: 0.02, configs_per_kernel: 4, ..Default::default() };
    let out = train::run(&dev, &cfg);
    let engine = Arc::new(Engine::new(&dir).unwrap());
    let enc = train::encode_for_serving(&out.forest, &engine.manifest);
    let svc = Service::start(
        engine,
        enc,
        ServiceConfig {
            max_batch: 256,
            max_wait: std::time::Duration::from_micros(50),
            queue_depth: 64, // tiny queue to provoke backpressure
        },
    )
    .unwrap();
    let h = svc.handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let mut rng = Rng::new(1);
    let mut accepted = 0usize;
    let mut rejected = 0usize;
    for i in 0..5000u64 {
        let mut f = [0.0; NUM_FEATURES];
        for x in f.iter_mut() {
            *x = rng.range_f64(0.0, 10.0);
        }
        match h.submit(i, f, tx.clone()) {
            Ok(()) => accepted += 1,
            Err(_) => rejected += 1, // queue full: backpressure works
        }
    }
    drop(tx);
    let mut got = 0;
    while rx.recv().is_ok() {
        got += 1;
    }
    assert_eq!(got, accepted);
    drop(h);
    let stats = svc.shutdown();
    assert_eq!(stats.served as usize, accepted);
    // On a 1-core box the burst must overflow the 64-deep queue at least
    // occasionally; if not, backpressure never engaged and the test is
    // vacuous — accept either but record the split.
    eprintln!("accepted={accepted} rejected={rejected} batches={}", stats.batches);
}

#[test]
fn corrupt_artifact_fails_loudly_not_silently() {
    let Some(dir) = artifacts() else { return };
    // Engine must refuse a mangled HLO file.
    let tmp = std::env::temp_dir().join(format!("lmtuner-art-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::copy(dir.join("manifest.json"), tmp.join("manifest.json")).unwrap();
    // provide one corrupt artifact
    std::fs::write(tmp.join("forest_b64.hlo.txt"), "HloModule garbage\nENTRY {").unwrap();
    let engine = Engine::new(&tmp).unwrap(); // lazy compile: ok
    let err = engine.execute("forest_b64.hlo.txt", &[]);
    assert!(err.is_err(), "corrupt artifact executed successfully?!");
    let missing = engine.execute("forest_b4096.hlo.txt", &[]);
    assert!(missing.is_err(), "missing artifact executed successfully?!");
    std::fs::remove_dir_all(&tmp).ok();
}
