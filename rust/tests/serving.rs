//! Integration tests over the runtime + coordinator serving stack.
//!
//! Everything here runs WITHOUT PJRT artifacts — the native batched
//! executor is the default backend, so these tests always execute in CI.
//! The one PJRT cross-check still auto-skips when artifacts/ is absent.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use lmtuner::coordinator::service::{Service, ServiceConfig};
use lmtuner::coordinator::train::{self, TrainConfig};
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::kernelmodel::features::NUM_FEATURES;
use lmtuner::ml::export::{encode, EncodedForest, ExportContract};
use lmtuner::ml::forest::{Forest, ForestConfig};
use lmtuner::ml::io as model_io;
use lmtuner::runtime::executor::{BatchExecutor, NativeForestExecutor};
use lmtuner::runtime::fastexec::FlatForestExecutor;
use lmtuner::runtime::forest_exec::ForestExecutor;
use lmtuner::runtime::pjrt::Engine;
use lmtuner::util::prng::Rng;

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping pjrt cross-check: run `make artifacts` first");
        None
    }
}

/// A quick forest over random data, encoded under the default contract.
fn toy_encoded(seed: u64, trees: usize) -> EncodedForest {
    let mut rng = Rng::new(seed);
    let x: Vec<Vec<f64>> = (0..NUM_FEATURES)
        .map(|_| (0..400).map(|_| rng.range_f64(-2.0, 2.0)).collect())
        .collect();
    let y: Vec<f64> = (0..400)
        .map(|i| if x[0][i] + x[3][i] > 0.0 { 1.0 } else { -1.0 })
        .collect();
    let forest = Forest::fit(
        &x,
        &y,
        &ForestConfig { num_trees: trees, threads: 2, ..Default::default() },
    );
    encode(&forest, ExportContract::default())
}

fn random_rows(n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..NUM_FEATURES).map(|_| rng.range_f64(-4.0, 4.0)).collect())
        .collect()
}

#[test]
fn native_executor_matches_encoded_reference_on_10k_rows() {
    // Acceptance: the native batched executor agrees with
    // `EncodedForest::predict` to 1e-6 on every row of a 10k-row batch.
    let enc = toy_encoded(0xA11CE, 20);
    let exec = NativeForestExecutor::with_parallelism(enc.clone(), 4, 128);
    let rows = random_rows(10_000, 0xBEE5);
    let got = exec.predict(&rows).unwrap();
    assert_eq!(got.len(), rows.len());
    for (i, (row, g)) in rows.iter().zip(&got).enumerate() {
        let want = enc.predict(row);
        assert!(
            (g - want).abs() < 1e-6,
            "row {i}: batched {g} vs reference {want}"
        );
    }
}

#[test]
fn service_roundtrip_with_zero_artifacts() {
    // Acceptance: the full service round trip — concurrent clients,
    // batching, shutdown accounting — with no PJRT artifacts present.
    let enc = toy_encoded(0x5EEDED, 12);
    let svc = Service::start_native(
        enc.clone(),
        ServiceConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            workers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let h = svc.handle();

    let mut clients = Vec::new();
    for t in 0..4u64 {
        let h = h.clone();
        let enc = enc.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x1000 + t);
            for _ in 0..50 {
                let mut feats = [0.0; NUM_FEATURES];
                for f in feats.iter_mut() {
                    *f = rng.range_f64(-2.0, 2.0);
                }
                let resp = h.predict(feats).unwrap();
                let want = enc.predict(&feats);
                assert!((resp.score - want).abs() < 1e-9, "{} vs {want}", resp.score);
                assert_eq!(resp.use_local_memory, want > 0.0);
                assert!(resp.batch_size >= 1);
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let stats = svc.shutdown();
    assert_eq!(stats.served, 200);
    assert_eq!(stats.rejected, 0);
}

#[test]
fn trained_pipeline_serves_natively_end_to_end() {
    // Phase 1 (train) -> encode -> phase 2 (serve) with no artifacts.
    let dev = DeviceSpec::m2090();
    let cfg = TrainConfig { scale: 0.02, configs_per_kernel: 4, ..Default::default() };
    let out = train::run(&dev, &cfg);
    let enc = train::encode_default(&out.forest);
    assert_eq!(enc.truncated, 0, "default contract must fit the forest");

    let svc = Service::start_native(
        enc.clone(),
        ServiceConfig {
            max_batch: 256,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        },
    )
    .unwrap();
    let h = svc.handle();
    let mut sent = 0u64;
    for r in out.records.iter().take(200) {
        let resp = h.predict(r.base.features).unwrap();
        let want = enc.predict(&r.base.features);
        assert!((resp.score - want).abs() < 1e-9);
        sent += 1;
    }
    assert!(sent > 0, "pipeline produced no records to serve");
    let stats = svc.shutdown();
    assert_eq!(stats.served, sent);
}

#[test]
fn service_survives_bursts_and_reports_backpressure() {
    let enc = toy_encoded(0xB00, 10);
    let svc = Service::start_native(
        enc,
        ServiceConfig {
            max_batch: 256,
            max_wait: Duration::from_micros(50),
            queue_depth: 64, // tiny queue to provoke backpressure
            workers: 1,
        },
    )
    .unwrap();
    let h = svc.handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let mut rng = Rng::new(1);
    let mut accepted = 0usize;
    let mut rejected = 0usize;
    for i in 0..5000u64 {
        let mut f = [0.0; NUM_FEATURES];
        for x in f.iter_mut() {
            *x = rng.range_f64(0.0, 10.0);
        }
        match h.submit(i, f, tx.clone()) {
            Ok(()) => accepted += 1,
            Err(_) => rejected += 1, // queue full: backpressure works
        }
    }
    drop(tx);
    let mut got = 0;
    while let Ok(reply) = rx.recv() {
        reply.unwrap();
        got += 1;
    }
    assert_eq!(got, accepted);
    let stats = svc.shutdown();
    assert_eq!(stats.served as usize, accepted);
    // On a 1-core box the burst may overflow the 64-deep queue; accept
    // either outcome but record the split.
    eprintln!("accepted={accepted} rejected={rejected} batches={}", stats.batches);
}

#[test]
fn shutdown_with_live_client_handle_regression() {
    // Regression for the old clone-and-drop shutdown: with any live
    // client handle the worker never saw the channel disconnect and
    // `Service::shutdown` hung forever. The explicit shutdown protocol
    // must complete regardless of live handles.
    let enc = toy_encoded(0xD00D, 8);
    let svc = Service::start_native(
        enc,
        ServiceConfig { workers: 2, ..Default::default() },
    )
    .unwrap();
    let h = svc.handle();

    // Serve one request so the workers are demonstrably running.
    let resp = h.predict([0.5; NUM_FEATURES]).unwrap();
    assert!(resp.batch_size >= 1);

    let held = h.clone(); // stays alive across shutdown
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = done_tx.send(svc.shutdown());
    });
    let stats = done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("shutdown hung while a client handle was still held");
    assert_eq!(stats.served, 1);

    // The surviving handle gets a clean error, not a hang.
    let err = held.predict([0.0; NUM_FEATURES]).unwrap_err();
    assert!(format!("{err}").contains("service stopped"), "{err}");
}

struct FlakyExec {
    inner: NativeForestExecutor,
    fail: std::sync::atomic::AtomicBool,
}

impl BatchExecutor for FlakyExec {
    fn backend(&self) -> &'static str {
        "flaky"
    }
    fn max_batch(&self) -> usize {
        64
    }
    fn predict(&self, rows: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        if self.fail.swap(false, std::sync::atomic::Ordering::SeqCst) {
            anyhow::bail!("transient backend failure");
        }
        self.inner.predict(rows)
    }
}

#[test]
fn batch_failure_is_a_typed_error_and_service_recovers() {
    // One failed batch must produce typed error replies (not dropped
    // channels) and the next batch must serve normally.
    let enc = toy_encoded(0xFA11, 8);
    let exec = FlakyExec {
        inner: NativeForestExecutor::new(enc.clone()),
        fail: std::sync::atomic::AtomicBool::new(true),
    };
    let svc = Service::start_sharded(
        vec![exec],
        ServiceConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(50),
            ..Default::default()
        },
    )
    .unwrap();
    let h = svc.handle();

    let err = h.predict([1.0; NUM_FEATURES]).unwrap_err();
    assert!(
        format!("{err:#}").contains("transient backend failure"),
        "want the typed batch error, got: {err:#}"
    );

    // Recovered: subsequent requests serve through the real executor.
    let feats = [0.25; NUM_FEATURES];
    let resp = h.predict(feats).unwrap();
    assert!((resp.score - enc.predict(&feats)).abs() < 1e-9);

    let stats = svc.shutdown();
    assert_eq!(stats.served, 1);
    assert_eq!(stats.rejected, 1);
}

/// A joint (schema-v2) forest over random data: verdict plane plus
/// log2(wg_w) / log2(wg_h) extra planes.
fn toy_joint_forest(seed: u64, trees: usize) -> Forest {
    let mut rng = Rng::new(seed);
    let n = 400;
    let x: Vec<Vec<f64>> = (0..NUM_FEATURES)
        .map(|_| (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect())
        .collect();
    let y: Vec<f64> = (0..n)
        .map(|i| if x[0][i] + x[3][i] > 0.0 { 1.0 } else { -1.0 })
        .collect();
    let lw: Vec<f64> = (0..n).map(|i| if x[1][i] > 0.0 { 5.0 } else { 3.0 }).collect();
    let lh: Vec<f64> = (0..n).map(|i| if x[2][i] > 0.0 { 2.0 } else { 0.0 }).collect();
    Forest::fit_multi(
        &x,
        &y,
        &[lw, lh],
        &ForestConfig { num_trees: trees, threads: 2, ..Default::default() },
    )
}

#[test]
fn sharded_service_roundtrips_a_joint_model_through_the_flat_backend() {
    // Schema-v2 model -> disk -> load -> encode -> sharded service on
    // the flat backend: every response must carry the verdict AND the
    // workgroup suggestion from the same traversal, bit-equal to the
    // encoded reference.
    let forest = toy_joint_forest(0x2F1A7, 10);
    let tmp = std::env::temp_dir().join(format!("lmtuner-joint-{}.model", std::process::id()));
    model_io::save(&forest, &tmp).unwrap();
    let loaded = model_io::load(&tmp).unwrap();
    std::fs::remove_file(&tmp).ok();
    let enc = encode(&loaded, ExportContract::default());
    assert_eq!(enc.num_outputs(), 3, "joint model must encode 3 planes");

    let svc = Service::start_native(
        enc.clone(),
        ServiceConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(100),
            workers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let h = svc.handle();
    let mut rng = Rng::new(0x77AB);
    for _ in 0..200 {
        let mut feats = [0.0; NUM_FEATURES];
        for f in feats.iter_mut() {
            *f = rng.range_f64(-3.0, 3.0);
        }
        let resp = h.predict(feats).unwrap();
        let want = enc.predict(&feats);
        assert!((resp.score - want).abs() < 1e-9, "{} vs {want}", resp.score);
        assert_eq!(resp.use_local_memory, want > 0.0);
        let (gw, gh) = resp.wg_logs.expect("joint model must serve wg suggestions");
        let (ww, wh) = enc.predict_wg_logs(&feats).unwrap();
        assert_eq!(gw.to_bits(), ww.to_bits(), "wg width plane diverged");
        assert_eq!(gh.to_bits(), wh.to_bits(), "wg height plane diverged");
    }
    let stats = svc.shutdown();
    assert_eq!(stats.served, 200);

    // A single-output model serves wg_logs: None — the field is absent,
    // not fabricated.
    let enc1 = toy_encoded(0x51461E, 6);
    let svc1 = Service::start_native(enc1, ServiceConfig::default()).unwrap();
    let resp = svc1.handle().predict([0.5; NUM_FEATURES]).unwrap();
    assert!(resp.wg_logs.is_none(), "single-output model fabricated wg_logs");
    svc1.shutdown();
}

/// A shard wrapper: either a real flat executor or a permanently dead
/// one, for the fail-over test below.
enum ShardExec {
    Good(FlatForestExecutor),
    Dead,
}

impl BatchExecutor for ShardExec {
    fn backend(&self) -> &'static str {
        match self {
            ShardExec::Good(e) => e.backend(),
            ShardExec::Dead => "dead",
        }
    }
    fn max_batch(&self) -> usize {
        64
    }
    fn predict(&self, rows: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        match self {
            ShardExec::Good(e) => e.predict(rows),
            ShardExec::Dead => anyhow::bail!("injected dead shard"),
        }
    }
    fn num_outputs(&self) -> usize {
        match self {
            ShardExec::Good(e) => BatchExecutor::num_outputs(e),
            ShardExec::Dead => 1,
        }
    }
    fn predict_outputs(&self, rows: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        match self {
            ShardExec::Good(e) => BatchExecutor::predict_outputs(e, rows),
            ShardExec::Dead => anyhow::bail!("injected dead shard"),
        }
    }
}

#[test]
fn dead_shard_fails_its_requests_typed_while_the_live_shard_keeps_serving() {
    // Two shards, one permanently dead: requests round-robin across
    // them, so dead-shard requests must come back as typed errors while
    // live-shard requests keep serving correct scores — and the stats
    // must account for both sides exactly.
    let enc = toy_encoded(0xDEAD5, 8);
    let good = FlatForestExecutor::new(&enc).unwrap();
    let svc = Service::start_sharded(
        vec![ShardExec::Dead, ShardExec::Good(good)],
        ServiceConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(50),
            ..Default::default()
        },
    )
    .unwrap();
    let h = svc.handle();
    let mut ok = 0u64;
    let mut failed = 0u64;
    let mut rng = Rng::new(0xC0FFEE);
    for _ in 0..40 {
        let mut feats = [0.0; NUM_FEATURES];
        for f in feats.iter_mut() {
            *f = rng.range_f64(-2.0, 2.0);
        }
        // Blocking predict: each call lands on the next shard in the
        // round-robin, so both shards are exercised deterministically.
        match h.predict(feats) {
            Ok(resp) => {
                ok += 1;
                let want = enc.predict(&feats);
                assert!((resp.score - want).abs() < 1e-9);
            }
            Err(err) => {
                failed += 1;
                assert!(
                    format!("{err:#}").contains("injected dead shard"),
                    "want the injected typed error, got: {err:#}"
                );
            }
        }
    }
    assert!(ok > 0, "live shard served nothing");
    assert!(failed > 0, "dead shard never surfaced its error");
    let stats = svc.shutdown();
    assert_eq!(stats.served, ok);
    assert_eq!(stats.rejected, failed);
}

#[test]
fn corrupt_artifact_fails_loudly_not_silently() {
    let Some(dir) = artifacts() else { return };
    // Engine must refuse a mangled HLO file.
    let tmp = std::env::temp_dir().join(format!("lmtuner-art-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::copy(dir.join("manifest.json"), tmp.join("manifest.json")).unwrap();
    // provide one corrupt artifact
    std::fs::write(tmp.join("forest_b64.hlo.txt"), "HloModule garbage\nENTRY {").unwrap();
    let engine = Engine::new(&tmp).unwrap(); // lazy compile: ok
    let err = engine.execute("forest_b64.hlo.txt", &[]);
    assert!(err.is_err(), "corrupt artifact executed successfully?!");
    let missing = engine.execute("forest_b4096.hlo.txt", &[]);
    assert!(missing.is_err(), "missing artifact executed successfully?!");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn trained_model_serves_identically_native_and_pjrt() {
    let Some(dir) = artifacts() else { return };
    let dev = DeviceSpec::m2090();
    let cfg = TrainConfig { scale: 0.03, configs_per_kernel: 6, ..Default::default() };
    let out = train::run(&dev, &cfg);
    let engine = Arc::new(Engine::new(&dir).unwrap());
    let enc = train::encode_for_serving(&out.forest, &engine.manifest);
    let exec = ForestExecutor::new(engine, &enc).unwrap();

    let rows: Vec<Vec<f64>> = out
        .records
        .iter()
        .take(300)
        .map(|r| r.base.features.to_vec())
        .collect();
    let pjrt = exec.predict(&rows).unwrap();
    let native = NativeForestExecutor::new(enc.clone());
    let native_preds = native.predict(&rows).unwrap();
    let mut graded = 0;
    let mut agree = 0;
    for ((row, p), np) in rows.iter().zip(&pjrt).zip(&native_preds) {
        let reference = enc.predict(row);
        assert!((reference - p).abs() < 1e-4, "{reference} vs pjrt {p}");
        assert!((reference - np).abs() < 1e-6, "{reference} vs native {np}");
        let full = out.forest.predict(row);
        if full.abs() > 0.1 {
            graded += 1;
            agree += ((full > 0.0) == (*p > 0.0)) as usize;
        }
    }
    assert!(agree as f64 / graded.max(1) as f64 > 0.95, "{agree}/{graded}");
}
