//! Golden lint suite: the four frontend fixtures must lint clean (no
//! deny- or warn-level findings) under their documented bindings, each
//! seeded-defect variant under `fixtures/lint/` must fire exactly its
//! rule ID, diagnostics must anchor to the fixture line that carries the
//! defect, the `--json` document must round-trip through `util::json`,
//! and the staging certificate must say `stageable: yes` for every
//! Table 3 configuration the extractor reconciles (the sweep constants
//! mirror `tests/frontend.rs`).

use lmtuner::frontend::sema::CertReason;
use lmtuner::frontend::{
    self, parse_program, AnalyzeOptions, Bindings, LintReport, SemaOptions, Severity,
};
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::kernelmodel::launch::Launch;
use lmtuner::util::json::Json;
use lmtuner::workloads;

fn fixture(name: &str) -> String {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures")
        .join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

fn lint_src(src: &str, launch: Launch, bindings: Bindings) -> LintReport {
    let prog = parse_program(src).expect("fixture parses");
    let opts = SemaOptions { kernel: None, launch, bindings, certificates: true };
    frontend::lint_program(&prog, &opts, &DeviceSpec::m2090()).expect("lint runs")
}

fn lint_fixture(name: &str, launch: Launch, bindings: Bindings) -> LintReport {
    lint_src(&fixture(name), launch, bindings)
}

/// The golden fixtures with the bindings their doc headers document,
/// plus the target array the descriptor suite reconciles.
fn goldens() -> Vec<(&'static str, Launch, Bindings, &'static str)> {
    let conv = Bindings::new().set("width", 512).set("rows_per_thread", 1).set("radius", 2);
    vec![
        (
            "convolution_row.cl",
            workloads::launch_over((16, 16), (512, 512)),
            conv.clone(),
            "input",
        ),
        (
            "convolution_col.cl",
            workloads::launch_over((16, 16), (512, 512)),
            conv,
            "input",
        ),
        (
            "matrixmul.cl",
            workloads::launch_over((16, 8), (512, 512)),
            Bindings::new().set("size", 512).set("tile_k", 8),
            "b",
        ),
        (
            "transpose.cl",
            workloads::launch_over((16, 16), (1024, 1024)),
            Bindings::new().set("width", 1024).set("height", 1024),
            "output",
        ),
    ]
}

/// Rule IDs of every deny- or warn-level finding, in report order.
fn failing_ids(r: &LintReport) -> Vec<&'static str> {
    r.diags
        .iter()
        .filter(|d| d.severity >= Severity::Warn)
        .map(|d| d.rule.id())
        .collect()
}

#[test]
fn golden_fixtures_lint_clean_with_bindings() {
    for (name, launch, bindings, target) in goldens() {
        let r = lint_fixture(name, launch, bindings);
        assert_eq!(r.diags.deny_count(), 0, "{name}: {:?}", failing_ids(&r));
        assert_eq!(r.diags.warn_count(), 0, "{name}: {:?}", failing_ids(&r));
        // The reconciled target array must carry a positive certificate.
        let cert = r
            .certificates
            .iter()
            .find(|c| c.array == target)
            .unwrap_or_else(|| panic!("{name}: no certificate for `{target}`"));
        assert!(cert.stageable, "{name}: {}", cert.summary());
        assert!(cert.reasons.is_empty(), "{name}");
    }
}

#[test]
fn golden_fixtures_lint_clean_without_bindings() {
    // No --set bindings: the affine interval checks degrade to skipped
    // (values drop to Uniform/Variant) but nothing may deny or warn.
    for (name, launch, _, _) in goldens() {
        let r = lint_fixture(name, launch, Bindings::new());
        assert_eq!(failing_ids(&r), Vec::<&str>::new(), "{name}");
    }
}

#[test]
fn seeded_defects_fire_exactly_their_rule() {
    let cases = [
        ("lint/divergent_barrier.cl", Bindings::new().set("width", 512), "LM001"),
        ("lint/oob_tap.cl", Bindings::new().set("width", 512), "LM002"),
        ("lint/over_budget.cl", Bindings::new().set("size", 512), "LM003"),
        ("lint/bank_conflict.cl", Bindings::new().set("width", 512), "LM004"),
    ];
    let launch = workloads::launch_over((16, 16), (512, 512));
    for (name, bindings, want) in cases {
        let r = lint_fixture(name, launch, bindings);
        let ids = failing_ids(&r);
        assert!(!ids.is_empty(), "{name}: expected {want}, found nothing");
        assert!(
            ids.iter().all(|id| *id == want),
            "{name}: expected only {want}, got {ids:?}"
        );
    }
}

#[test]
fn diagnostics_anchor_to_the_defect_line() {
    // The regression the spans satellite guards: a defect on fixture
    // line N must be reported at line N (computed from the source, so
    // editing a fixture comment cannot silently invalidate the test).
    let launch = workloads::launch_over((16, 16), (512, 512));
    let cases = [
        ("lint/divergent_barrier.cl", "barrier(1)", "LM001"),
        ("lint/oob_tap.cl", "in[gy * width + gx + k]", "LM002"),
        ("lint/bank_conflict.cl", "out[gy * width + gx * 32]", "LM004"),
    ];
    for (name, needle, rule) in cases {
        let src = fixture(name);
        let want_line = src
            .lines()
            .position(|l| l.contains(needle))
            .unwrap_or_else(|| panic!("{name}: no line contains `{needle}`"))
            + 1;
        let r = lint_src(&src, launch, Bindings::new().set("width", 512).set("size", 512));
        let d = r
            .diags
            .iter()
            .find(|d| d.rule.id() == rule)
            .unwrap_or_else(|| panic!("{name}: {rule} did not fire"));
        assert_eq!(d.pos.line as usize, want_line, "{name}: {d}");
    }
}

#[test]
fn lint_json_round_trips_through_util_json() {
    let launch = workloads::launch_over((16, 16), (512, 512));
    let r = lint_fixture("lint/oob_tap.cl", launch, Bindings::new().set("width", 512));
    let doc = r.to_json("lint/oob_tap.cl");
    let back = Json::parse(&doc.dump_pretty()).expect("lint JSON parses back");
    assert_eq!(back, doc, "round trip must be lossless");

    assert_eq!(back.get("file").and_then(|f| f.as_str()), Some("lint/oob_tap.cl"));
    let summary = back.get("summary").expect("summary object");
    assert_eq!(summary.get("deny").and_then(Json::as_usize), Some(r.diags.deny_count()));
    assert_eq!(summary.get("warn").and_then(Json::as_usize), Some(r.diags.warn_count()));
    assert_eq!(summary.get("note").and_then(Json::as_usize), Some(r.diags.note_count()));

    let diags = back.get("diagnostics").and_then(Json::as_arr).expect("diagnostics array");
    assert_eq!(diags.len(), r.diags.len());
    assert!(
        diags.iter().any(|d| d.get("rule").and_then(|x| x.as_str()) == Some("LM002")),
        "{}",
        doc.dump_pretty()
    );
    let certs = back.get("certificates").and_then(Json::as_arr).expect("certificates array");
    assert_eq!(certs.len(), r.certificates.len());
    assert!(certs
        .iter()
        .all(|c| c.get("stageable").is_some() && c.get("array").is_some()));
}

#[test]
fn transpose_store_is_a_note_not_a_warning() {
    // The transpose epilogue store is exactly what the staging transform
    // exists to fix: LM005 must demote to Note on the one-off access.
    let r = lint_fixture(
        "transpose.cl",
        workloads::launch_over((16, 16), (1024, 1024)),
        Bindings::new().set("width", 1024).set("height", 1024),
    );
    let lm005: Vec<_> = r.diags.iter().filter(|d| d.rule.id() == "LM005").collect();
    assert!(!lm005.is_empty(), "transpose store should surface as LM005");
    assert!(
        lm005.iter().all(|d| d.severity == Severity::Note),
        "{:?}",
        lm005.iter().map(|d| d.to_string()).collect::<Vec<_>>()
    );
}

#[test]
fn bank_conflict_suppresses_uncoalesced_on_the_same_access() {
    let r = lint_fixture(
        "lint/bank_conflict.cl",
        workloads::launch_over((16, 16), (512, 512)),
        Bindings::new().set("width", 512),
    );
    let lm004 = r.diags.iter().filter(|d| d.rule.id() == "LM004").count();
    let lm005 = r.diags.iter().filter(|d| d.rule.id() == "LM005").count();
    assert_eq!(lm004, 1, "exactly one bank-conflict finding");
    assert_eq!(lm005, 0, "LM005 must be suppressed where LM004 fired");
}

#[test]
fn over_budget_lint_pairs_warning_with_certificate() {
    let r = lint_fixture(
        "lint/over_budget.cl",
        workloads::launch_over((16, 16), (512, 512)),
        Bindings::new().set("size", 512),
    );
    let lm003: Vec<_> = r.diags.iter().filter(|d| d.rule.id() == "LM003").collect();
    assert_eq!(lm003.len(), 1, "{:?}", failing_ids(&r));
    assert_eq!(lm003[0].array.as_deref(), Some("b"));

    let cert = r.certificates.iter().find(|c| c.array == "b").expect("certificate for b");
    assert!(!cert.stageable);
    assert!(
        cert.reasons.iter().any(|x| matches!(x, CertReason::OverBudget { .. })),
        "{}",
        cert.summary()
    );
    assert!(cert.region_bytes.unwrap() > cert.budget_bytes, "{}", cert.summary());
    assert!(cert.summary().starts_with("stageable: no"), "{}", cert.summary());

    // The output array stays stageable: the defect is b's alone.
    let out = r.certificates.iter().find(|c| c.array == "out").expect("certificate for out");
    assert!(out.stageable, "{}", out.summary());
}

// ---------------------------------------------------------------------
// Staging certificates across the full Table 3 sweep (the acceptance
// bar: every configuration the extractor reconciles must certify).
// Sweep constants mirror tests/frontend.rs; totals fail loudly on drift.

const CONV_RADII: [u32; 5] = [1, 2, 3, 4, 6];
const CONV_WGS: [(u32, u32); 5] = [(16, 4), (16, 16), (32, 4), (32, 8), (64, 4)];
const CONV_SIZES: [u32; 4] = [256, 512, 1024, 2048];
const CONV_RPT: [u32; 3] = [1, 2, 4];
const MM_SIZES: [u32; 2] = [512, 1024];
const MM_TILE_K: [u32; 3] = [4, 8, 16];
const MM_WGS: [(u32, u32); 11] = [
    (16, 4),
    (16, 8),
    (16, 16),
    (32, 2),
    (32, 4),
    (32, 8),
    (32, 16),
    (8, 8),
    (8, 16),
    (64, 2),
    (64, 4),
];
const TR_WGS: [(u32, u32); 7] =
    [(8, 8), (16, 8), (16, 16), (32, 8), (32, 16), (32, 32), (64, 4)];
const TR_SIZES: [u32; 3] = [512, 1024, 2048];

fn cert_opts(target: &str, launch: Launch, bindings: Bindings) -> AnalyzeOptions {
    AnalyzeOptions { target: target.into(), kernel: None, launch, bindings }
}

#[test]
fn every_table3_config_certifies_stageable() {
    let dev = DeviceSpec::m2090();
    let mut checked = 0usize;
    for pass in ["row", "col"] {
        let prog = parse_program(&fixture(&format!("convolution_{pass}.cl"))).unwrap();
        for &r in &CONV_RADII {
            for &wg in &CONV_WGS {
                for &size in &CONV_SIZES {
                    for &rpt in &CONV_RPT {
                        let launch = workloads::launch_over(wg, (size, size / rpt));
                        let b = Bindings::new()
                            .set("width", size as i64)
                            .set("rows_per_thread", rpt as i64)
                            .set("radius", r as i64);
                        let cert = frontend::certify(&prog, &cert_opts("input", launch, b), &dev);
                        assert!(
                            cert.stageable && cert.reasons.is_empty(),
                            "convolution_{pass} r{r} wg{}x{} {size} rpt{rpt}: {}",
                            wg.0,
                            wg.1,
                            cert.summary()
                        );
                        checked += 1;
                    }
                }
            }
        }
    }
    let prog = parse_program(&fixture("matrixmul.cl")).unwrap();
    for &size in &MM_SIZES {
        for &tk in &MM_TILE_K {
            for &wg in &MM_WGS {
                let launch = workloads::launch_over(wg, (size, size));
                let b = Bindings::new().set("size", size as i64).set("tile_k", tk as i64);
                let cert = frontend::certify(&prog, &cert_opts("b", launch, b), &dev);
                assert!(
                    cert.stageable && cert.reasons.is_empty(),
                    "matrixMul {size} k{tk} wg{}x{}: {}",
                    wg.0,
                    wg.1,
                    cert.summary()
                );
                checked += 1;
            }
        }
    }
    let prog = parse_program(&fixture("transpose.cl")).unwrap();
    for &size in &TR_SIZES {
        for &wg in &TR_WGS {
            let launch = workloads::launch_over(wg, (size, size));
            let b = Bindings::new().set("width", size as i64).set("height", size as i64);
            let cert = frontend::certify(&prog, &cert_opts("output", launch, b), &dev);
            assert!(
                cert.stageable && cert.reasons.is_empty(),
                "transpose {size} wg{}x{}: {}",
                wg.0,
                wg.1,
                cert.summary()
            );
            checked += 1;
        }
    }
    assert_eq!(checked, 687, "must cover every Table 3 instance");
}
