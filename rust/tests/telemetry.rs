//! Telemetry-plane integration suite (DESIGN.md §2i).
//!
//! Exercises the contracts the rest of the system leans on: exact
//! power-of-two histogram bucket boundaries, order-independent merges
//! across worker thread counts, the bounded-error percentile estimate
//! against exact quantiles, deterministic span trees under an injected
//! clock, and the `metrics.json` round trip through `util::json`.

use std::sync::Arc;
use std::time::Duration;

use lmtuner::obs::metrics::{bucket_hi, bucket_lo, Histogram, MetricsRegistry, MIN_EXP, NUM_BUCKETS};
use lmtuner::obs::trace::{Clock, ManualClock, Tracer};
use lmtuner::util::json::Json;
use lmtuner::util::prng::Rng;
use lmtuner::util::stats;

/// The single nonzero bucket index of a one-observation histogram.
fn sole_bucket(v: f64) -> usize {
    let mut h = Histogram::new();
    h.observe(v);
    let nz = h.nonzero_buckets();
    assert_eq!(nz.len(), 1, "one observation lands in one bucket");
    assert_eq!(nz[0].1, 1);
    nz[0].0
}

#[test]
fn bucket_boundaries_are_exact_at_powers_of_two() {
    // A power of two is the inclusive lower edge of its bucket: 2^k and
    // the next representable float below it land in adjacent buckets.
    for k in -20..=20i32 {
        let v = (2f64).powi(k);
        let below = f64::from_bits(v.to_bits() - 1);
        let i = sole_bucket(v);
        let j = sole_bucket(below);
        assert_eq!(i, j + 1, "2^{k} must open a new bucket");
        assert_eq!(bucket_lo(i), v, "2^{k} is its bucket's lower edge");
        assert_eq!(bucket_hi(i), 2.0 * v);
        assert_eq!(bucket_hi(j), v, "the bucket below closes exactly at 2^{k}");
    }
    // Edges of the bucket array: non-positive and non-finite values
    // route to bucket 0 (so bucket sums always equal the count), huge
    // finite values saturate the last bucket.
    assert_eq!(sole_bucket(0.0), 0);
    assert_eq!(sole_bucket(-3.5), 0);
    assert_eq!(sole_bucket(f64::NAN), 0);
    assert_eq!(sole_bucket(f64::INFINITY), 0);
    assert_eq!(sole_bucket(1e300), NUM_BUCKETS - 1);
    assert_eq!(sole_bucket((2f64).powi(MIN_EXP - 7)), 0);
    assert!(bucket_lo(0).is_infinite() && bucket_lo(0) < 0.0);
    assert!(bucket_hi(NUM_BUCKETS - 1).is_infinite());
}

/// Deterministic log-uniform latency-like samples spanning ~9 octaves.
fn samples(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (2f64).powf(rng.range_f64(-13.0, -4.0))).collect()
}

/// One worker's registry over its shard of the sample stream.
fn shard_registry(shard: &[f64], worker: usize) -> MetricsRegistry {
    let mut reg = MetricsRegistry::new();
    for &v in shard {
        reg.add("telemetry.observed", 1);
        reg.observe("telemetry.latency_s", v);
    }
    reg.set_gauge("telemetry.peak", shard.iter().cloned().fold(0.0, f64::max));
    reg.add(&format!("telemetry.worker{worker}.observed"), shard.len() as u64);
    reg
}

#[test]
fn merges_are_associative_and_commutative_across_thread_counts() {
    let xs = samples(0xC0FFEE, 4096);
    let mut merged: Vec<MetricsRegistry> = Vec::new();
    for threads in [1usize, 2, 4] {
        // Real worker threads, each folding its own shard — the same
        // ownership pattern the service workers use.
        let chunk = xs.len().div_ceil(threads);
        let shards: Vec<MetricsRegistry> = std::thread::scope(|s| {
            let handles: Vec<_> = xs
                .chunks(chunk)
                .map(|c| s.spawn(move || shard_registry(c, 0)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Forward fold, reverse fold, and a right-associated fold must
        // agree bit-for-bit: bucket counts are u64 sums and gauges are
        // maxes, so order cannot matter.
        let mut fwd = MetricsRegistry::new();
        for r in &shards {
            fwd.merge(r);
        }
        let mut rev = MetricsRegistry::new();
        for r in shards.iter().rev() {
            rev.merge(r);
        }
        let mut right = shards.last().cloned().unwrap();
        for r in shards.iter().rev().skip(1) {
            let mut acc = r.clone();
            acc.merge(&right);
            right = acc;
        }
        assert_eq!(fwd, rev, "{threads} threads: forward == reverse");
        assert_eq!(fwd, right, "{threads} threads: fold order is irrelevant");
        merged.push(fwd);
    }
    // ... and sharding itself must not change the result.
    assert_eq!(merged[0], merged[1], "1-thread == 2-thread totals");
    assert_eq!(merged[0], merged[2], "1-thread == 4-thread totals");
    let h = merged[0].histogram("telemetry.latency_s").unwrap();
    assert_eq!(h.count(), xs.len() as u64);
}

#[test]
fn percentile_estimate_stays_within_one_octave_of_exact() {
    for seed in [1u64, 7, 42, 0xBEEF] {
        let xs = samples(seed, 1000);
        let mut h = Histogram::new();
        for &v in &xs {
            h.observe(v);
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [10.0, 50.0, 90.0, 99.0] {
            let est = h.percentile(p);
            // The estimate is the upper edge of the bucket holding the
            // rank-th smallest sample (clamped to the observed range):
            // never below the exact quantile, never more than 2x it.
            let rank = ((p / 100.0 * xs.len() as f64).ceil() as usize).max(1);
            let exact = sorted[rank - 1];
            assert!(
                est >= exact && est <= 2.0 * exact,
                "seed {seed} p{p}: est {est} outside [{exact}, {}]",
                2.0 * exact
            );
            // Cross-check against the interpolating oracle: it is >= the
            // order statistic, so the one-octave ceiling transfers.
            assert!(est <= 2.0 * stats::percentile(&xs, p));
        }
        assert!(h.percentile(50.0) <= h.percentile(90.0));
        assert!(h.percentile(90.0) <= h.percentile(99.0));
        assert!(h.percentile(100.0) <= h.max());
    }
}

/// `ManualClock` handle the test keeps while the tracer owns the
/// `Box<dyn Clock>` — both sides see the same atomic nanos.
#[derive(Clone)]
struct SharedClock(Arc<ManualClock>);

impl Clock for SharedClock {
    fn now(&self) -> Duration {
        self.0.now()
    }
}

fn scripted_trace() -> Tracer {
    let clock = Arc::new(ManualClock::new());
    let tracer = Tracer::with_clock(Box::new(SharedClock(Arc::clone(&clock))));
    tracer.retain_events();
    {
        let _outer = tracer.span("train");
        clock.advance(Duration::from_millis(3));
        {
            let _inner = tracer.span("fit");
            clock.advance(Duration::from_millis(10));
        }
        {
            let _inner = tracer.span("grade");
            clock.advance(Duration::from_millis(4));
        }
        clock.advance(Duration::from_millis(1));
    }
    tracer
}

#[test]
fn span_tree_is_deterministic_under_an_injected_clock() {
    let a = scripted_trace();
    let b = scripted_trace();

    let events = a.events();
    assert_eq!(events.len(), 3);
    // Children close before the parent, so they retire first.
    let fit = &events[0];
    let grade = &events[1];
    let outer = &events[2];
    assert_eq!((fit.name.as_str(), fit.path.as_str()), ("fit", "train/fit"));
    assert_eq!(grade.path, "train/grade");
    assert_eq!(outer.parent, None);
    assert_eq!(fit.parent, Some(outer.id));
    assert_eq!(grade.parent, Some(outer.id));
    // Exact wall-time attribution off the manual clock.
    assert_eq!(fit.elapsed(), Duration::from_millis(10));
    assert_eq!(grade.elapsed(), Duration::from_millis(4));
    assert_eq!(outer.elapsed(), Duration::from_millis(18));

    // Two identical schedules produce identical trees and renders.
    let attr = |t: &Tracer| {
        t.attribution()
            .into_iter()
            .map(|(path, s)| (path, s.count, s.total))
            .collect::<Vec<_>>()
    };
    assert_eq!(attr(&a), attr(&b));
    assert_eq!(a.render_tree(), b.render_tree());
    let tree = a.render_tree();
    assert!(tree.contains("train"), "{tree}");
    assert!(tree.contains("fit") && tree.contains("grade"), "{tree}");
}

#[test]
fn metrics_json_round_trips_through_util_json() {
    let mut reg = MetricsRegistry::new();
    reg.add("pipeline.records", 12_345);
    reg.add("stage.dedup.dropped", 17);
    reg.set_gauge("train.fit_s", 1.25);
    reg.set_gauge("serve.req_per_s", 98_765.4321);
    for &v in &samples(99, 500) {
        reg.observe("serve.exec_s", v);
    }
    reg.observe("serve.batch_rows", 4096.0);

    let text = reg.to_json().dump();
    let parsed = Json::parse(&text).expect("registry JSON parses back");
    let back = MetricsRegistry::from_json(&parsed).expect("registry decodes");
    assert_eq!(back, reg, "dump -> parse -> decode is the identity");

    // Percentiles survive the trip (they derive from the buckets).
    let h = reg.histogram("serve.exec_s").unwrap();
    let hb = back.histogram("serve.exec_s").unwrap();
    for p in [50.0, 90.0, 99.0] {
        assert_eq!(h.percentile(p), hb.percentile(p));
    }

    // A tampered payload (bucket counts no longer sum to the total)
    // is rejected rather than decoded into an inconsistent histogram.
    let tampered = text.replacen("\"count\":500", "\"count\":499", 1);
    assert_ne!(tampered, text, "tamper target must exist in the dump");
    let parsed = Json::parse(&tampered).unwrap();
    assert!(MetricsRegistry::from_json(&parsed).is_err());

    // Writing through the same path `--metrics-out` uses.
    let dir = std::env::temp_dir().join(format!("lmtuner-metrics-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("metrics.json");
    reg.write(&path).unwrap();
    let from_disk =
        MetricsRegistry::from_json(&Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap())
            .unwrap();
    assert_eq!(from_disk, reg);
    std::fs::remove_dir_all(&dir).ok();
}
