//! Differential test layer for the flattened inference hot path
//! (`runtime::fastexec`).
//!
//! The flat executor is the default serving backend, so every claim it
//! makes is pinned here against the tensor-walking reference
//! (`EncodedForest::predict` / `NativeForestExecutor`):
//!
//!   * float path: bit-equal to the reference over randomized forests
//!     (varied tree counts, truncating and padded contracts, 1- and
//!     3-output planes, duplicated thresholds from the binned trainer);
//!   * quantized path: bit-equal when the cut tables are exact (the
//!     default-trained case), decision-equivalent row-for-row on
//!     10k-row batches at every thread count;
//!   * NaN/±inf feature rows route deterministically exactly like the
//!     reference (`NaN <= t` is false → right) and never panic;
//!   * malformed batches produce the same typed errors as the
//!     reference executor, message-for-message;
//!   * lossy cut tables (>255 distinct thresholds on a feature) are
//!     detected, `Auto` mode falls back to float, and the forced
//!     quantized path stays deterministic with high decision agreement.

use std::sync::Arc;

use lmtuner::kernelmodel::features::NUM_FEATURES;
use lmtuner::ml::export::{encode, EncodedForest, ExportContract};
use lmtuner::ml::forest::{Forest, ForestConfig};
use lmtuner::ml::tree::{Node, Tree};
use lmtuner::prop_assert;
use lmtuner::runtime::executor::{BatchExecutor, NativeForestExecutor};
use lmtuner::runtime::fastexec::{FlatForest, FlatForestExecutor, FlatMode};
use lmtuner::util::prng::Rng;
use lmtuner::util::prop;

/// Random column-major training data over the full feature width.
fn training_data(rng: &mut Rng, n: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
    let x: Vec<Vec<f64>> = (0..NUM_FEATURES)
        .map(|_| (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect())
        .collect();
    let y: Vec<f64> = (0..n)
        .map(|i| if x[1][i] + 0.5 * x[4][i] > 0.0 { 1.0 } else { -1.0 })
        .collect();
    (x, y)
}

fn fit_single(rng: &mut Rng, trees: usize) -> Forest {
    let (x, y) = training_data(rng, 300);
    let cfg = ForestConfig {
        num_trees: trees,
        threads: 2,
        seed: rng.below(1 << 20),
        ..Default::default()
    };
    Forest::fit(&x, &y, &cfg)
}

fn fit_joint(rng: &mut Rng, trees: usize) -> Forest {
    let (x, y) = training_data(rng, 300);
    let lw: Vec<f64> = (0..300).map(|i| if x[0][i] > 0.0 { 5.0 } else { 2.0 }).collect();
    let lh: Vec<f64> = (0..300).map(|i| if x[2][i] > 0.0 { 3.0 } else { 1.0 }).collect();
    let cfg = ForestConfig {
        num_trees: trees,
        threads: 2,
        seed: rng.below(1 << 20),
        ..Default::default()
    };
    Forest::fit_multi(&x, &y, &[lw, lh], &cfg)
}

fn random_rows(n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..NUM_FEATURES).map(|_| rng.range_f64(-4.0, 4.0)).collect())
        .collect()
}

/// Reference outputs, row-major, via the (fixed) single-pass encoded walk.
fn reference_outputs(enc: &EncodedForest, rows: &[Vec<f64>]) -> Vec<f64> {
    rows.iter().flat_map(|r| enc.predict_outputs(r)).collect()
}

#[test]
fn float_path_is_bit_equal_to_the_reference_over_randomized_forests() {
    // Varied forests x varied contracts: padded (more contract slots
    // than trees — exercises zero-tree dropping and the scale
    // correction), and truncating (tiny node/depth budget — exercises
    // subtree-mean leaves). Binned training reuses thresholds across
    // trees, so duplicated thresholds are covered by construction.
    prop::check("flat-float == encoded reference", 10, |rng| {
        let trees = 1 + rng.below(6) as usize;
        let joint = rng.below(2) == 1;
        let forest =
            if joint { fit_joint(rng, trees) } else { fit_single(rng, trees) };
        let contract = if rng.below(2) == 1 {
            // padded: contract wants more trees than the forest has
            ExportContract {
                num_trees: trees + 1 + rng.below(8) as usize,
                max_nodes: 8192,
                max_depth: 64,
                ..Default::default()
            }
        } else {
            // truncating: tiny budgets force subtree-mean leaves
            ExportContract {
                num_trees: trees,
                max_nodes: 16,
                max_depth: 3 + rng.below(3) as usize,
                ..Default::default()
            }
        };
        let enc = encode(&forest, contract);
        let flat = FlatForest::compile(&enc)
            .map_err(|e| format!("compile failed: {e}"))?;
        prop_assert!(
            flat.num_outputs() == enc.num_outputs(),
            "outputs {} vs {}",
            flat.num_outputs(),
            enc.num_outputs()
        );
        let rows = random_rows(64, 0xF10A7 + rng.below(1 << 30));
        let got = flat.predict_outputs_batch(&rows, FlatMode::Float);
        let want = reference_outputs(&enc, &rows);
        prop_assert!(got.len() == want.len(), "{} vs {}", got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            prop_assert!(
                g.to_bits() == w.to_bits(),
                "output {i}: flat {g:?} vs reference {w:?} \
                 (trees={trees} joint={joint} contract={contract:?})"
            );
        }
        // Joint executors agree with the reference executor's batched
        // wg path too (same traversal, same (w, h) pairs).
        if joint {
            let fx = FlatForestExecutor::new(&enc)
                .map_err(|e| format!("{e}"))?
                .mode(FlatMode::Float);
            let nx = NativeForestExecutor::new(enc.clone());
            let a = fx.predict_wg_logs(&rows).map_err(|e| format!("{e}"))?;
            let b = nx.predict_wg_logs(&rows).map_err(|e| format!("{e}"))?;
            prop_assert!(a == b, "wg logs diverged");
        }
        Ok(())
    });
}

#[test]
fn quantized_is_exact_and_decision_equivalent_on_10k_rows_at_every_thread_count() {
    let mut rng = Rng::new(0x10AD);
    for (joint, seed) in [(false, 0xAAu64), (true, 0xBBu64)] {
        let forest = if joint { fit_joint(&mut rng, 8) } else { fit_single(&mut rng, 8) };
        let enc = encode(&forest, ExportContract::default());
        let flat = Arc::new(FlatForest::compile(&enc).unwrap());
        // Default (binned) training draws thresholds from <=256 cuts per
        // feature, so the quantized tables must be exact.
        assert!(flat.quantized_exact(), "binned forest must quantize exactly");
        let rows = random_rows(10_000, seed);
        let want = reference_outputs(&enc, &rows);
        let k = enc.num_outputs();
        for threads in [1usize, 2, 4, 8] {
            for mode in [FlatMode::Float, FlatMode::Quantized, FlatMode::Auto] {
                let exec =
                    FlatForestExecutor::with_parallelism(flat.clone(), threads, 128)
                        .mode(mode);
                let got = exec.predict_outputs(&rows).unwrap();
                assert_eq!(got.len(), rows.len() * k);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        g.to_bits() == w.to_bits(),
                        "joint={joint} threads={threads} mode={mode:?} \
                         output {i}: {g:?} vs {w:?}"
                    );
                }
                // Decision equivalence is implied by bit-equality, but
                // assert it through the trait path `decide` uses.
                let decisions = exec.decide(&rows[..256]).unwrap();
                for (i, d) in decisions.iter().enumerate() {
                    assert_eq!(*d, enc.decide(&rows[i]), "row {i} decision");
                }
            }
        }
    }
}

#[test]
fn nan_and_inf_rows_route_like_the_reference_and_never_panic() {
    let mut rng = Rng::new(0xF00D);
    for joint in [false, true] {
        let forest = if joint { fit_joint(&mut rng, 6) } else { fit_single(&mut rng, 6) };
        let enc = encode(&forest, ExportContract::default());
        let flat = Arc::new(FlatForest::compile(&enc).unwrap());
        assert!(flat.quantized_exact());
        // Rows seeded with NaN / +inf / -inf in random positions, plus
        // all-NaN and all-inf rows.
        let mut rows = random_rows(500, 0x11F + joint as u64);
        for (i, row) in rows.iter_mut().enumerate() {
            let f = i % NUM_FEATURES;
            row[f] = match i % 3 {
                0 => f64::NAN,
                1 => f64::INFINITY,
                _ => f64::NEG_INFINITY,
            };
        }
        rows.push(vec![f64::NAN; NUM_FEATURES]);
        rows.push(vec![f64::INFINITY; NUM_FEATURES]);
        rows.push(vec![f64::NEG_INFINITY; NUM_FEATURES]);
        let want = reference_outputs(&enc, &rows);
        for mode in [FlatMode::Float, FlatMode::Quantized] {
            for threads in [1usize, 4] {
                let exec =
                    FlatForestExecutor::with_parallelism(flat.clone(), threads, 64)
                        .mode(mode);
                let got = exec.predict_outputs(&rows).unwrap();
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        g.to_bits() == w.to_bits(),
                        "joint={joint} mode={mode:?} threads={threads} \
                         output {i}: {g:?} vs reference {w:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn typed_error_parity_with_the_reference_executor() {
    let mut rng = Rng::new(0xE44);
    let enc = encode(&fit_single(&mut rng, 5), ExportContract::default());
    let flat = FlatForestExecutor::new(&enc).unwrap();
    let native = NativeForestExecutor::new(enc.clone());

    // Empty batches succeed with empty results on both.
    assert!(flat.predict(&[]).unwrap().is_empty());
    assert!(native.predict(&[]).unwrap().is_empty());
    assert!(flat.predict_outputs(&[]).unwrap().is_empty());

    // Short/long rows: identical message, including the row index.
    for bad_width in [0usize, NUM_FEATURES - 1, NUM_FEATURES + 3] {
        let rows = vec![vec![0.0; NUM_FEATURES], vec![0.5; bad_width]];
        let ef = flat.predict(&rows).unwrap_err();
        let en = native.predict(&rows).unwrap_err();
        assert_eq!(format!("{ef}"), format!("{en}"), "width {bad_width}");
        assert!(format!("{ef}").contains("row 1"), "{ef}");
    }

    // Workgroup prediction on a single-output model: identical typed
    // error on both executors.
    let rows = random_rows(4, 0x77);
    let ef = flat.predict_wg_logs(&rows).unwrap_err();
    let en = native.predict_wg_logs(&rows).unwrap_err();
    assert_eq!(format!("{ef}"), format!("{en}"));
    assert!(format!("{ef}").contains("joint"), "{ef}");

    // Arity agreement through the trait.
    assert_eq!(flat.num_outputs(), native.num_outputs());

    // A joint model agrees on arity and on the wg error-free path.
    let jenc = encode(&fit_joint(&mut rng, 5), ExportContract::default());
    let jf = FlatForestExecutor::new(&jenc).unwrap();
    let jn = NativeForestExecutor::new(jenc.clone());
    assert_eq!(jf.num_outputs(), 3);
    assert_eq!(jf.num_outputs(), jn.num_outputs());
    assert_eq!(
        jf.predict_wg_logs(&rows).unwrap(),
        jn.predict_wg_logs(&rows).unwrap()
    );
}

/// A balanced depth-`d` tree splitting only on feature 0 with all-distinct
/// dyadic thresholds: depth 9 yields 511 distinct thresholds on one
/// feature — past the 255-cut table capacity, forcing the lossy path.
fn dense_threshold_tree(depth: usize, rng: &mut Rng) -> Tree {
    fn build(lo: f64, hi: f64, d: usize, nodes: &mut Vec<Node>, rng: &mut Rng) -> usize {
        let idx = nodes.len();
        if d == 0 {
            nodes.push(Node::Leaf { value: if rng.below(2) == 1 { 1.0 } else { -1.0 } });
            return idx;
        }
        let mid = 0.5 * (lo + hi);
        nodes.push(Node::Split { feature: 0, threshold: mid, left: 0, right: 0, mean: 0.0 });
        let l = build(lo, mid, d - 1, nodes, rng);
        let r = build(mid, hi, d - 1, nodes, rng);
        if let Node::Split { left, right, .. } = &mut nodes[idx] {
            *left = l;
            *right = r;
        }
        idx
    }
    let mut nodes = Vec::new();
    build(0.0, 1.0, depth, &mut nodes, rng);
    let t = Tree { nodes, extra: Vec::new() };
    t.validate().expect("hand-built tree must be structurally valid");
    t
}

#[test]
fn lossy_quantization_is_detected_deterministic_and_auto_falls_back_to_float() {
    let mut rng = Rng::new(0x10557);
    let forest = Forest {
        trees: vec![dense_threshold_tree(9, &mut rng)],
        config_summary: "hand-built dense-threshold tree".to_string(),
    };
    let contract = ExportContract {
        num_trees: 1,
        max_nodes: 2048,
        max_depth: 16,
        ..Default::default()
    };
    let enc = encode(&forest, contract);
    assert_eq!(enc.truncated, 0, "the dense tree must fit the contract");
    let flat = Arc::new(FlatForest::compile(&enc).unwrap());
    assert!(
        !flat.quantized_exact(),
        "511 distinct thresholds cannot fit a 255-cut table"
    );
    // Auto never runs an inexact table: it resolves to the float path,
    // which stays bit-equal to the reference.
    assert_eq!(flat.resolve_mode(FlatMode::Auto), FlatMode::Float);
    let auto_exec = FlatForestExecutor::from_shared(flat.clone());
    assert_eq!(auto_exec.backend(), "flat");
    let mut rows = random_rows(2000, 0xD1CE);
    for row in rows.iter_mut() {
        row[0] = (row[0] + 4.0) / 8.0; // into the tree's (0, 1) domain
    }
    rows.push(vec![f64::NAN; NUM_FEATURES]);
    rows.push(vec![f64::INFINITY; NUM_FEATURES]);
    let want: Vec<f64> = rows.iter().map(|r| enc.predict(r)).collect();
    let auto_got = auto_exec.predict(&rows).unwrap();
    for (g, w) in auto_got.iter().zip(&want) {
        assert_eq!(g.to_bits(), w.to_bits(), "auto(float) diverged");
    }
    // Forced quantized: approximate, but never panics, routes every row
    // to a real leaf, is deterministic run-to-run, and agrees with the
    // reference on the vast majority of decisions (the drift window is
    // the gap between a snapped cut and its true threshold).
    let q = FlatForestExecutor::from_shared(flat.clone()).mode(FlatMode::Quantized);
    assert_eq!(q.backend(), "flat-q");
    let q1 = q.predict(&rows).unwrap();
    let q2 = q.predict(&rows).unwrap();
    assert_eq!(q1, q2, "lossy quantized path must be deterministic");
    let leaf_values = [1.0, -1.0, 0.0]; // 0.0 never predicted, ±1 leaves
    for g in &q1 {
        assert!(
            leaf_values.iter().any(|v| (g - v).abs() < 1e-12),
            "quantized output {g} is not a real leaf value"
        );
    }
    let agree = q1
        .iter()
        .zip(&want)
        .filter(|(g, w)| (**g > 0.0) == (**w > 0.0))
        .count();
    let rate = agree as f64 / want.len() as f64;
    assert!(rate >= 0.9, "decision agreement {rate:.3} below 0.9");
}
