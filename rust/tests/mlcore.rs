//! ml-v2 equivalence + determinism suite.
//!
//! The binned split engine must be provably interchangeable with the
//! exact sort-based reference (DESIGN.md §ml-v2): identical results
//! where the binning is lossless (constant targets, <= 256 distinct
//! values per feature), and both paper metrics within 0.5% on the
//! continuous crossdev-style synthetic dataset. `lmtuner tune`'s
//! cross-validation must be bitwise deterministic at any thread count.

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::ml::forest::{Forest, ForestConfig};
use lmtuner::ml::metrics;
use lmtuner::ml::select::{self, GridSpec, TuneConfig};
use lmtuner::ml::tree::{SplitEngine, Tree, TreeConfig};
use lmtuner::sim::exec::{MeasureConfig, SpeedupRecord, TuneRecord};
use lmtuner::synth::{dataset, generator, sweep::LaunchSweep};
use lmtuner::util::prng::Rng;

fn engine_cfg(base: ForestConfig, engine: SplitEngine) -> ForestConfig {
    let mut cfg = base;
    cfg.tree.engine = engine;
    cfg
}

/// Small crossdev-style synthetic dataset: the same generator ->
/// sweep -> simulated-measure path `lmtuner crossdev` trains on.
fn crossdev_synthetic(scale: f64, configs_per_kernel: usize) -> Vec<TuneRecord> {
    let dev = DeviceSpec::m2090();
    let mut rng = Rng::new(0x5EED ^ 0xDA7A);
    let templates = generator::generate(&mut rng, scale);
    let sweep = LaunchSweep::new(2048, 2048);
    let cfg = dataset::BuildConfig {
        configs_per_kernel,
        measure: MeasureConfig::deterministic(),
        ..Default::default()
    };
    dataset::build(&templates, &sweep, &dev, &cfg)
}

// ---- shape 1: constant target ---------------------------------------

#[test]
fn equivalence_constant_target() {
    // Both engines must collapse a constant target to a single leaf per
    // tree, predicting the constant exactly.
    let x: Vec<Vec<f64>> = (0..3)
        .map(|f| (0..200).map(|i| ((i * (f + 1)) % 37) as f64).collect())
        .collect();
    let y = vec![1.75; 200];
    for engine in [SplitEngine::Exact, SplitEngine::Binned] {
        let cfg = engine_cfg(
            ForestConfig { num_trees: 5, threads: 2, ..Default::default() },
            engine,
        );
        let f = Forest::fit(&x, &y, &cfg);
        for t in &f.trees {
            assert_eq!(t.nodes.len(), 1, "{engine:?}");
        }
        assert_eq!(f.predict(&[3.0, 5.0, 7.0]), 1.75, "{engine:?}");
    }
}

// ---- shape 2: step function (lossless binning) ----------------------

#[test]
fn equivalence_step_function_identical_trees() {
    // One sample per distinct value, splits confined to the single
    // informative feature: the binning is lossless and both engines
    // must grow byte-identical trees from the same seed.
    let n = 240;
    let rows: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64, 1.0]).collect();
    let x: Vec<Vec<f64>> = (0..2)
        .map(|f| rows.iter().map(|r| r[f]).collect())
        .collect();
    let y: Vec<f64> = (0..n)
        .map(|i| match i {
            0..=59 => -2.0,
            60..=149 => 0.25,
            _ => 1.5,
        })
        .collect();
    for seed in [1u64, 9, 42] {
        let cfg = TreeConfig { mtry: 2, ..TreeConfig::default() };
        let mut idx_e: Vec<usize> = (0..n).collect();
        let mut idx_b: Vec<usize> = (0..n).collect();
        let mut rng_e = Rng::new(seed);
        let mut rng_b = Rng::new(seed);
        let te = Tree::fit(
            &x,
            &y,
            &mut idx_e,
            TreeConfig { engine: SplitEngine::Exact, ..cfg },
            &mut rng_e,
        );
        let tb = Tree::fit(
            &x,
            &y,
            &mut idx_b,
            TreeConfig { engine: SplitEngine::Binned, ..cfg },
            &mut rng_b,
        );
        assert_eq!(te.nodes, tb.nodes, "seed {seed}");
        for i in 0..n {
            assert_eq!(te.predict(&rows[i]), tb.predict(&rows[i]), "i={i}");
        }
    }
}

// ---- shape 3: crossdev synthetic (continuous features) --------------

#[test]
fn equivalence_crossdev_synthetic_metrics_within_half_percent() {
    // Continuous simulator features: binning is quantized, so individual
    // trees differ — but averaged over forest seeds, both paper metrics
    // must agree within 0.5 percentage points, and the two engines'
    // decisions must agree on the overwhelming majority of held-out
    // instances.
    let records = crossdev_synthetic(0.05, 8);
    assert!(records.len() > 2500, "{} records", records.len());
    let (train, test) = dataset::split(&records, 0.1, 3);
    let train: Vec<&SpeedupRecord> = train.iter().map(|r| &r.base).collect();
    let test: Vec<&SpeedupRecord> = test.iter().map(|r| &r.base).collect();

    let seeds = [0xF0_4E57u64, 0xA11CE, 0xB0B];
    let mut count = [0.0f64; 2];
    let mut penalty = [0.0f64; 2];
    for &seed in &seeds {
        let mut forests = Vec::new();
        for engine in [SplitEngine::Exact, SplitEngine::Binned] {
            let cfg = engine_cfg(
                ForestConfig { seed, threads: 2, ..Default::default() },
                engine,
            );
            forests.push(Forest::fit_records(&train, &cfg).expect("finite records"));
        }
        let mut agree = 0usize;
        for r in test.iter() {
            agree += (forests[0].decide(&r.features) == forests[1].decide(&r.features))
                as usize;
        }
        assert!(
            agree as f64 / test.len() as f64 > 0.95,
            "engines disagree on {}/{} held-out decisions (seed {seed})",
            test.len() - agree,
            test.len()
        );
        for (k, f) in forests.iter().enumerate() {
            let a = metrics::evaluate_model(&test, |x| f.decide(x));
            count[k] += a.count_based / seeds.len() as f64;
            penalty[k] += a.penalty_weighted / seeds.len() as f64;
        }
    }
    assert!(count[0] > 0.7, "exact engine count accuracy {}", count[0]);
    assert!(
        (count[0] - count[1]).abs() <= 0.005,
        "count-based accuracy drifted: exact {} vs binned {}",
        count[0],
        count[1]
    );
    assert!(
        (penalty[0] - penalty[1]).abs() <= 0.005,
        "penalty-weighted accuracy drifted: exact {} vs binned {}",
        penalty[0],
        penalty[1]
    );
}

// ---- lmtuner tune determinism ---------------------------------------

#[test]
fn tune_is_deterministic_at_any_thread_count() {
    let records = crossdev_synthetic(0.02, 4);
    assert!(records.len() >= 200, "{} records", records.len());
    let grid = GridSpec {
        num_trees: vec![5, 10],
        mtry: vec![2, 4],
        min_samples_leaf: vec![1],
    };
    let run = |threads: usize| {
        select::cross_validate(
            &records,
            &grid,
            &TuneConfig { folds: 3, seed: 0x7E57, threads, ..Default::default() },
        )
        .unwrap()
    };
    let a = run(1);
    let b = run(4);
    let c = run(4); // repeatability at the same thread count
    assert_eq!(a.best, b.best);
    assert_eq!(b.best, c.best);
    assert_eq!(a.scores.len(), 4);
    for ((sa, sb), sc) in a.scores.iter().zip(&b.scores).zip(&c.scores) {
        // every metric bitwise identical; only wall times may differ
        assert_eq!(sa.count_based, sb.count_based);
        assert_eq!(sa.count_std, sb.count_std);
        assert_eq!(sa.penalty_weighted, sb.penalty_weighted);
        assert_eq!(sa.min_score, sb.min_score);
        assert_eq!(sb.count_based, sc.count_based);
        assert_eq!(sb.penalty_weighted, sc.penalty_weighted);
        assert_eq!(sa.config.num_trees, sb.config.num_trees);
        assert_eq!(sa.config.tree.mtry, sb.config.tree.mtry);
    }
    // the winner's persisted form round-trips into a train-consumable
    // ForestConfig
    let path = std::env::temp_dir()
        .join(format!("lmtuner-mlcore-best-{}.txt", std::process::id()));
    select::save_forest_config(&a.best_score().config, &path).unwrap();
    let back = select::load_forest_config(&path).unwrap();
    assert_eq!(back.num_trees, a.best_score().config.num_trees);
    assert_eq!(back.tree.mtry, a.best_score().config.tree.mtry);
    std::fs::remove_file(&path).ok();
}
