//! Cross-module integration tests: generator -> simulator -> trainer ->
//! metrics -> persistence, plus property checks on system invariants.

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::kernelmodel::features::{extract, NUM_FEATURES};
use lmtuner::kernelmodel::launch::Launch;
use lmtuner::ml::export::{encode, ExportContract};
use lmtuner::ml::forest::{Forest, ForestConfig};
use lmtuner::ml::metrics;
use lmtuner::sim::exec::{measure, MeasureConfig, SpeedupRecord, TuneRecord};
use lmtuner::sim::timing::{simulate, Variant};
use lmtuner::synth::{dataset, generator, sweep::LaunchSweep};
use lmtuner::util::prng::Rng;
use lmtuner::util::prop;
use lmtuner::workloads;

fn small_records() -> Vec<TuneRecord> {
    let dev = DeviceSpec::m2090();
    let mut rng = Rng::new(42);
    let templates = generator::generate_n(&mut rng, 5);
    let sweep = LaunchSweep::new(2048, 2048);
    let cfg = dataset::BuildConfig { configs_per_kernel: 8, ..Default::default() };
    dataset::build(&templates, &sweep, &dev, &cfg)
}

#[test]
fn pipeline_learns_the_simulator() {
    let records = small_records();
    assert!(records.len() > 3000);
    let (train, test) = dataset::split(&records, 0.2, 1);
    let train: Vec<&SpeedupRecord> = train.iter().map(|r| &r.base).collect();
    let test: Vec<&SpeedupRecord> = test.iter().map(|r| &r.base).collect();
    let forest = Forest::fit_records(&train, &ForestConfig::default()).expect("finite records");
    let acc = metrics::evaluate_model(&test, |x| forest.decide(x));
    assert!(acc.count_based > 0.72, "count {}", acc.count_based);
    assert!(acc.penalty_weighted > 0.92, "penalty {}", acc.penalty_weighted);
}

#[test]
fn encoded_forest_preserves_decisions_end_to_end() {
    let records = small_records();
    let (train, test) = dataset::split(&records, 0.2, 2);
    let train: Vec<&SpeedupRecord> = train.iter().map(|r| &r.base).collect();
    let forest = Forest::fit_records(&train, &ForestConfig::default()).expect("finite records");
    let enc = encode(&forest, ExportContract::default());
    enc.validate().unwrap();
    let mut agree = 0usize;
    let mut graded = 0usize;
    for r in test.iter().take(2000) {
        let native = forest.predict(&r.base.features);
        if native.abs() < 0.05 {
            continue; // boundary cases may flip under f32 + truncation
        }
        graded += 1;
        agree += (enc.decide(&r.base.features) == (native > 0.0)) as usize;
    }
    assert!(
        agree as f64 / graded as f64 > 0.98,
        "{agree}/{graded} decisions agree"
    );
}

#[test]
fn model_roundtrip_through_disk_and_metrics() {
    let records = small_records();
    let (train, test) = dataset::split(&records, 0.2, 3);
    let train: Vec<&SpeedupRecord> = train.iter().map(|r| &r.base).collect();
    let test: Vec<&SpeedupRecord> = test.iter().map(|r| &r.base).collect();
    let forest = Forest::fit_records(&train, &ForestConfig {
        num_trees: 8,
        ..Default::default()
    })
    .expect("finite records");
    let dir = std::env::temp_dir();
    let path = dir.join(format!("lmtuner-int-{}.model", std::process::id()));
    lmtuner::ml::io::save(&forest, &path).unwrap();
    let loaded = lmtuner::ml::io::load(&path).unwrap();
    let a = metrics::evaluate_model(&test, |x| forest.decide(x));
    let b = metrics::evaluate_model(&test, |x| loaded.decide(x));
    assert_eq!(a.count_based, b.count_based);
    assert_eq!(a.penalty_weighted, b.penalty_weighted);
    std::fs::remove_file(&path).ok();
}

#[test]
fn real_benchmarks_flow_through_the_same_feature_space() {
    let dev = DeviceSpec::m2090();
    let cfg = MeasureConfig::deterministic();
    for b in workloads::all() {
        for d in (b.instances)(&dev).iter().take(5) {
            let r = measure(d, &dev, &cfg);
            assert_eq!(r.features.len(), NUM_FEATURES);
            // Oracle consistency: the record's own decision matches a
            // fresh simulation pair.
            let base = simulate(d, &dev, Variant::Baseline);
            let opt = simulate(d, &dev, Variant::Optimized);
            if opt.feasible() {
                let s = base.time_s / opt.time_s;
                assert!((s.clamp(0.01, 100.0) - r.speedup).abs() < 1e-9);
            } else {
                assert!(!r.beneficial());
            }
        }
    }
}

// ---- property tests over system invariants -------------------------

#[test]
fn prop_speedup_invariant_under_feature_noise_free_measure() {
    // Measuring the same descriptor twice gives identical records.
    let dev = DeviceSpec::m2090();
    let sweep = LaunchSweep::new(2048, 2048);
    prop::check("measure-deterministic", 64, |rng| {
        let mut trng = rng.fork(1);
        let t = &generator::generate_n(&mut trng, 1)[rng.range(0, 111)];
        let launch = sweep.all()[rng.range(0, sweep.len() - 1)];
        let d = t.descriptor(&launch, &dev);
        let cfg = MeasureConfig::default();
        let a = measure(&d, &dev, &cfg);
        let b = measure(&d, &dev, &cfg);
        lmtuner::prop_assert!(a.speedup == b.speedup, "nondeterministic");
        lmtuner::prop_assert!(
            a.features == b.features,
            "feature extraction nondeterministic"
        );
        Ok(())
    });
}

#[test]
fn prop_infeasible_regions_never_beneficial() {
    let dev = DeviceSpec::m2090();
    let sweep = LaunchSweep::new(2048, 2048);
    prop::check("infeasible-never-wins", 128, |rng| {
        let mut trng = rng.fork(2);
        let ts = generator::generate_n(&mut trng, 2);
        let t = &ts[rng.range(0, ts.len() - 1)];
        let launch = sweep.all()[rng.range(0, sweep.len() - 1)];
        let d = t.descriptor(&launch, &dev);
        if !d.lmem_feasible(&dev) {
            let r = measure(&d, &dev, &MeasureConfig::deterministic());
            lmtuner::prop_assert!(
                !r.beneficial(),
                "{} infeasible but beneficial ({}x)",
                d.name,
                r.speedup
            );
        }
        Ok(())
    });
}

#[test]
fn prop_occupancy_monotone_in_resources() {
    use lmtuner::gpu::occupancy::{occupancy, BlockUsage};
    let dev = DeviceSpec::m2090();
    prop::check("occupancy-monotone", 256, |rng| {
        let threads = 32 * rng.range(1, 32) as u32;
        let regs = rng.range(8, 63) as u32;
        let smem = rng.range(0, 48 * 1024) as u32;
        let o1 = occupancy(&dev, &BlockUsage {
            threads_per_block: threads,
            regs_per_thread: regs,
            shared_bytes_per_block: smem,
        });
        let o2 = occupancy(&dev, &BlockUsage {
            threads_per_block: threads,
            regs_per_thread: regs,
            shared_bytes_per_block: smem + 1024,
        });
        lmtuner::prop_assert!(
            o2.blocks_per_sm <= o1.blocks_per_sm,
            "more smem increased occupancy: {o1:?} -> {o2:?}"
        );
        Ok(())
    });
}

#[test]
fn prop_batching_decisions_equal_unbatched() {
    // The encoded forest gives identical answers whatever the batch mix.
    let records = small_records();
    let (train, _) = dataset::split(&records, 0.1, 5);
    let train: Vec<&SpeedupRecord> = train.iter().map(|r| &r.base).collect();
    let forest = Forest::fit_records(&train, &ForestConfig {
        num_trees: 5,
        ..Default::default()
    })
    .expect("finite records");
    let enc = encode(&forest, ExportContract::default());
    prop::check("batch-invariance", 32, |rng| {
        let i = rng.range(0, records.len() - 1);
        let single = enc.predict(&records[i].base.features);
        // same row surrounded by arbitrary others
        let j = rng.range(0, records.len() - 1);
        let batch = [
            records[j].base.features.to_vec(),
            records[i].base.features.to_vec(),
        ];
        let again = enc.predict(&batch[1]);
        lmtuner::prop_assert!(single == again, "batch position changed result");
        Ok(())
    });
}

#[test]
fn prop_native_executor_invariant_under_batch_mix() {
    // The native BatchExecutor returns the same value for a row whether
    // it is served alone, in a shuffled batch, or across chunk splits.
    use lmtuner::runtime::executor::{BatchExecutor, NativeForestExecutor};
    let records = small_records();
    let (train, _) = dataset::split(&records, 0.1, 7);
    let train: Vec<&SpeedupRecord> = train.iter().map(|r| &r.base).collect();
    let forest = Forest::fit_records(&train, &ForestConfig {
        num_trees: 5,
        ..Default::default()
    })
    .expect("finite records");
    let enc = encode(&forest, ExportContract::default());
    let exec = NativeForestExecutor::with_parallelism(enc.clone(), 3, 4);
    prop::check("native-batch-invariance", 32, |rng| {
        let n = rng.range(1, 40);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                records[rng.range(0, records.len() - 1)].base.features.to_vec()
            })
            .collect();
        let batched = exec.predict(&rows).map_err(|e| e.to_string())?;
        for (row, b) in rows.iter().zip(&batched) {
            let single = enc.predict(row);
            lmtuner::prop_assert!(
                *b == single,
                "batched {b} != single {single}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_launch_sweep_all_descriptors_valid() {
    let dev = DeviceSpec::m2090();
    let sweep = LaunchSweep::new(2048, 2048);
    prop::check("descriptor-validity", 128, |rng| {
        let mut trng = rng.fork(3);
        let ts = generator::generate_n(&mut trng, 1);
        let t = &ts[rng.range(0, ts.len() - 1)];
        let launch: Launch = sweep.all()[rng.range(0, sweep.len() - 1)];
        let d = t.descriptor(&launch, &dev);
        let f = extract(&d);
        lmtuner::prop_assert!(
            f.iter().all(|x| x.is_finite()),
            "non-finite feature in {}",
            d.name
        );
        lmtuner::prop_assert!(d.reuse > 0.0, "non-positive reuse");
        lmtuner::prop_assert!(
            d.region_rows > 0 && d.region_cols > 0,
            "empty region"
        );
        Ok(())
    });
}
