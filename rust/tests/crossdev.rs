//! End-to-end tests for the device-portfolio layer: per-device training,
//! the cross-device accuracy matrix, and enforcement of the dataset
//! device-metadata contract across the sharded pipeline.

use lmtuner::coordinator::crossdev::{self, CrossDevConfig};
use lmtuner::coordinator::train::{self, ShardedTrainConfig, TrainConfig};
use lmtuner::gpu::registry;
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::sim::exec::MeasureConfig;
use lmtuner::synth::sink;

fn tmpdir(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lmtuner-xdev-{name}-{}", std::process::id()))
}

fn tiny() -> TrainConfig {
    TrainConfig {
        scale: 0.02,
        configs_per_kernel: 4,
        train_fraction: 0.5,
        measure: MeasureConfig::deterministic(),
        ..Default::default()
    }
}

#[test]
fn training_on_different_devices_produces_different_outcomes() {
    let a = train::run(&DeviceSpec::m2090(), &tiny());
    let b = train::run(&DeviceSpec::k20(), &tiny());
    assert_eq!(a.device, "m2090");
    assert_eq!(b.device, "k20");
    // Same synthetic population, different testbed: the measured label
    // distribution must actually change, otherwise the portfolio is a
    // no-op.
    assert!(
        a.summary.beneficial != b.summary.beneficial
            || a.summary.geomean_speedup() != b.summary.geomean_speedup(),
        "m2090 and k20 produced identical dataset summaries"
    );
}

#[test]
fn crossdev_matrix_covers_the_registered_portfolio() {
    // >= 4 devices registered; matrix is n x n with sane accuracies and
    // the CSV lands on disk with one row per training device.
    let devices = registry::all();
    let n = devices.len();
    assert!(n >= 4);
    let m = crossdev::run(&CrossDevConfig {
        base: tiny(),
        devices,
        dump: None,
    })
    .unwrap();
    assert_eq!(m.n(), n);
    assert_eq!(m.devices, registry::keys());
    for row in &m.count_based {
        assert_eq!(row.len(), n);
    }
    let out = tmpdir("matrix").join("crossdev.csv");
    m.to_csv(&out).unwrap();
    let body = std::fs::read_to_string(&out).unwrap();
    let lines: Vec<&str> = body.lines().collect();
    assert_eq!(lines.len(), n + 1);
    assert_eq!(
        lines[0],
        format!("train_device,{}", registry::keys().join(","))
    );
    for (i, key) in registry::keys().iter().enumerate() {
        assert!(lines[i + 1].starts_with(&format!("{key},")), "{}", lines[i + 1]);
        assert_eq!(lines[i + 1].split(',').count(), n + 1);
    }
    // the acceptance bar: same-device accuracy at least matches the
    // cross-device average
    assert!(
        m.diagonal_mean() >= m.off_diagonal_mean(),
        "diagonal {:.3} < off-diagonal {:.3}\n{}",
        m.diagonal_mean(),
        m.off_diagonal_mean(),
        m.render()
    );
    std::fs::remove_dir_all(tmpdir("matrix")).ok();
}

#[test]
fn sharded_training_stamps_the_device_and_rejects_foreign_shards() {
    let dir = tmpdir("enforce");
    let cfg = ShardedTrainConfig {
        shards: 2,
        train_capacity: 100,
        ..ShardedTrainConfig::new(tiny(), dir.clone())
    };
    let out = train::run_sharded(&DeviceSpec::gtx680(), &cfg, None).unwrap();
    assert_eq!(out.device, "gtx680");

    // The shards on disk carry the stamp...
    let (records, stream) = sink::load_sharded_tagged(&dir).unwrap();
    assert_eq!(stream.device.as_deref(), Some("gtx680"));
    assert_eq!(stream.schema, lmtuner::sim::exec::Schema::V1);
    assert_eq!(records.len() as u64, out.summary.records);

    // ...and a foreign shard poisons the whole directory with the typed
    // mismatch error instead of silently blending two devices' labels.
    let p = sink::shard_path(&dir, 1);
    let body = std::fs::read_to_string(&p).unwrap();
    std::fs::write(&p, body.replace("# device=gtx680", "# device=m2090")).unwrap();
    let err = sink::load_sharded(&dir).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("device mismatch"), "{msg}");
    assert!(msg.contains("gtx680") && msg.contains("m2090"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn registry_devices_disagree_on_occupancy_for_a_register_heavy_kernel() {
    // A quick cross-device sanity: a 512-thread, 63-register block fills
    // exactly one Fermi SM, while the K20's doubled register file keeps
    // two resident — the portfolio genuinely changes the parallelism
    // story the simulator tells.
    use lmtuner::gpu::occupancy::{occupancy, BlockUsage};
    let u = BlockUsage {
        threads_per_block: 512,
        regs_per_thread: 63,
        shared_bytes_per_block: 0,
    };
    let fermi = occupancy(&DeviceSpec::m2090(), &u);
    let kepler = occupancy(&DeviceSpec::k20(), &u);
    assert_eq!(fermi.blocks_per_sm, 1);
    assert!(kepler.blocks_per_sm > fermi.blocks_per_sm);
}
