//! Integration tests for the streaming dataset subsystem: the sink
//! layer's persistence contract, reservoir determinism, and the
//! bounded-memory behavior of the chunked builder at elevated scale.

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::sim::exec::TuneRecord;
use lmtuner::synth::sink::{
    load_sharded, stream_sharded, MemorySink, RecordSink, ReservoirSink, ShardedCsvSink, Tee,
};
use lmtuner::synth::{dataset, generator, sweep::LaunchSweep};
use lmtuner::util::prng::Rng;

fn tmpdir(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lmtuner-it-{name}-{}", std::process::id()))
}

fn setup(
    tuples: usize,
    configs: usize,
) -> (Vec<lmtuner::kernelmodel::template::Template>, LaunchSweep, DeviceSpec, dataset::BuildConfig)
{
    let mut rng = Rng::new(0x57E4);
    let templates = generator::generate_n(&mut rng, tuples);
    let sweep = LaunchSweep::new(2048, 2048);
    let dev = DeviceSpec::m2090();
    let cfg = dataset::BuildConfig {
        configs_per_kernel: configs,
        ..Default::default()
    };
    (templates, sweep, dev, cfg)
}

#[test]
fn sharded_write_reload_equals_in_memory_build() {
    let (templates, sweep, dev, cfg) = setup(3, 6);
    let reference = dataset::build(&templates, &sweep, &dev, &cfg);
    assert!(reference.len() > 1000, "{} rows", reference.len());

    for shards in [1usize, 5] {
        let dir = tmpdir(&format!("rt-{shards}"));
        let mut sink = ShardedCsvSink::create(&dir, shards, dev.key).unwrap();
        let summary =
            dataset::build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, None)
                .unwrap();
        assert_eq!(summary.records as usize, reference.len());
        assert_eq!(sink.written() as usize, reference.len());

        let back = load_sharded(&dir).unwrap();
        assert_eq!(back.len(), reference.len(), "shards={shards}");
        for (i, (a, b)) in back.iter().zip(&reference).enumerate() {
            assert_eq!(a.base.features, b.base.features, "row {i}, shards={shards}");
            assert!(
                (a.base.speedup - b.base.speedup).abs() < 1e-9,
                "row {i}: {} vs {}",
                a.base.speedup,
                b.base.speedup
            );
            assert_eq!(a.best_wg, b.best_wg, "row {i}, shards={shards}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn reservoir_sample_is_deterministic_and_sized() {
    let (templates, sweep, dev, cfg) = setup(2, 5);
    let run = || {
        let mut sink = ReservoirSink::new(200, 0xCAFE);
        dataset::build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, None)
            .unwrap();
        sink.into_sample()
    };
    let (recs_a, idx_a) = run();
    let (recs_b, idx_b) = run();
    assert_eq!(recs_a.len(), 200);
    assert_eq!(idx_a, idx_b);
    for (a, b) in recs_a.iter().zip(&recs_b) {
        assert_eq!(a.base.features, b.base.features);
        assert_eq!(a.base.speedup, b.base.speedup);
    }
    // indices are distinct and within the stream
    let total = dataset::build(&templates, &sweep, &dev, &cfg).len() as u64;
    let mut sorted = idx_a.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), 200);
    assert!(sorted.iter().all(|&i| i < total));
}

/// Sink that counts records without keeping any — the observer for the
/// bounded-memory contract.
#[derive(Default)]
struct CountingSink {
    n: u64,
}

impl RecordSink for CountingSink {
    fn accept(&mut self, _rec: &TuneRecord) -> anyhow::Result<()> {
        self.n += 1;
        Ok(())
    }
}

#[test]
fn bounded_memory_smoke_at_elevated_scale() {
    // 10 tuples = 1120 templates; with a tiny chunk the builder must
    // hand records over incrementally: each progress step may add at
    // most chunk_templates x configs_per_kernel records, so nothing
    // ever materializes more than a couple of in-flight chunks.
    let (templates, sweep, dev, mut cfg) = setup(10, 4);
    cfg.chunk_templates = 16;
    let chunk_bound = (cfg.chunk_templates * cfg.configs_per_kernel) as u64;

    let mut sink = CountingSink::default();
    let mut last_records = 0u64;
    let mut max_step = 0u64;
    let mut steps = 0usize;
    let mut cb = |p: &dataset::BuildProgress| {
        let step = p.records - last_records;
        last_records = p.records;
        max_step = max_step.max(step);
        steps += 1;
    };
    let summary =
        dataset::build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, Some(&mut cb))
            .unwrap();

    assert_eq!(sink.n, summary.records);
    assert!(summary.records > 3000, "{} records", summary.records);
    // one progress step per chunk, every chunk bounded
    assert_eq!(steps, (templates.len() + 15) / 16);
    assert!(
        max_step <= chunk_bound,
        "a chunk surfaced {max_step} records (> bound {chunk_bound})"
    );
}

#[test]
fn tee_shards_and_samples_in_one_pass() {
    // The single-pass train layout: shard to disk while the reservoir
    // draws the training split; the shards hold the full stream and
    // the reservoir indices point into it.
    let (templates, sweep, dev, cfg) = setup(2, 4);
    let dir = tmpdir("tee");
    let mut shards = ShardedCsvSink::create(&dir, 3, dev.key).unwrap();
    let mut reservoir = ReservoirSink::new(100, 42);
    let mut tee = Tee(&mut shards, &mut reservoir);
    dataset::build_streaming(&templates, &sweep, &dev, &cfg, &mut tee, None).unwrap();

    let selected = reservoir.selected_indices();
    assert_eq!(selected.len(), 100);
    let (sample, indices) = reservoir.into_sample();

    // Walking the shards, the sampled indices carry the sampled rows.
    let mut matched = 0usize;
    let stream = stream_sharded(&dir, |idx, rec| {
        if let Some(pos) = indices.iter().position(|&i| i == idx) {
            assert_eq!(rec.base.features, sample[pos].base.features);
            matched += 1;
        }
        Ok(())
    })
    .unwrap();
    assert_eq!(matched, 100);
    assert!(stream.rows > 400);
    assert_eq!(stream.device.as_deref(), Some(dev.key));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streamed_memory_sink_equals_classic_build() {
    // The public `build` is itself the streaming path; cross-check it
    // against the serial reference at integration scale.
    let (templates, sweep, dev, cfg) = setup(2, 6);
    let serial = dataset::build_serial(&templates, &sweep, &dev, &cfg);
    let mut sink = MemorySink::new();
    dataset::build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, None).unwrap();
    assert_eq!(sink.records.len(), serial.len());
    for (a, b) in sink.records.iter().zip(&serial) {
        assert_eq!(a.base.name, b.base.name);
        assert_eq!(a.base.features, b.base.features);
        assert_eq!(a.base.speedup, b.base.speedup);
        assert_eq!(a.best_wg, b.best_wg);
    }
}
