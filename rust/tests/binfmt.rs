//! End-to-end tests for the paper-scale data plane: the binary columnar
//! shard format, CSV <-> binary equivalence, content-based format
//! detection, and the composable pipeline stages under the streaming
//! builder — all through the public API, the way the CLI drives it.

use std::path::PathBuf;

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::kernelmodel::features::NUM_FEATURES;
use lmtuner::sim::exec::{MeasureConfig, Schema, TuneRecord};
use lmtuner::synth::binfmt::{BinShardWriter, CorruptShard, ShardFormat};
use lmtuner::synth::dataset::{self, BuildConfig};
use lmtuner::synth::pipeline::{PipelineSpec, StagedSink};
use lmtuner::synth::sink::{self, FormatMismatch, MemorySink, RecordSink, ShardedSink};
use lmtuner::synth::{generator, sweep::LaunchSweep};
use lmtuner::util::prng::Rng;

fn tmpdir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("lmtuner-binfmt-{name}-{}", std::process::id()))
}

/// A deterministic record whose every column is f32-exact, so the
/// binary format's f32 column planes round-trip it bit-identically.
/// Every fifth v2 record carries the (0, 0) = unlabeled sentinel.
fn record(i: usize, schema: Schema) -> TuneRecord {
    let mut row = vec![0.0; schema.columns()];
    for (j, cell) in row.iter_mut().take(NUM_FEATURES).enumerate() {
        *cell = (i * 31 + j) as f64 * 0.5;
    }
    row[NUM_FEATURES] = 0.25 + (i % 7) as f64;
    if schema == Schema::V2 && i % 5 != 0 {
        row[NUM_FEATURES + 1] = (1u32 << (i % 5)) as f64;
        row[NUM_FEATURES + 2] = (1u32 << (i % 3)) as f64;
    }
    TuneRecord::from_csv_row(schema, format!("r{i}"), &row).unwrap()
}

#[test]
fn binary_shards_roundtrip_bit_identically_with_csv() {
    for schema in [Schema::V1, Schema::V2] {
        let recs: Vec<TuneRecord> = (0..257).map(|i| record(i, schema)).collect();
        let base = tmpdir(&format!("rt-{schema}"));
        for format in [ShardFormat::Csv, ShardFormat::Bin] {
            let dir = base.join(format.as_str());
            let mut s =
                ShardedSink::create(&dir, 3, "m2090", schema, format).unwrap();
            for r in &recs {
                s.accept(r).unwrap();
            }
            s.finish().unwrap();
        }
        let (csv, ct) = sink::load_sharded_tagged(&base.join("csv")).unwrap();
        let (bin, bt) = sink::load_sharded_tagged(&base.join("bin")).unwrap();
        assert_eq!(ct.format, ShardFormat::Csv);
        assert_eq!(bt.format, ShardFormat::Bin);
        for t in [&ct, &bt] {
            assert_eq!(t.schema, schema);
            assert_eq!(t.device.as_deref(), Some("m2090"));
            assert_eq!(t.rows, recs.len() as u64);
        }
        let mut sentinels = 0usize;
        for ((a, b), orig) in csv.iter().zip(&bin).zip(&recs) {
            // bit equality between the two on-disk formats AND the
            // original stream: every column was chosen f32-exact
            assert_eq!(a.base.features, b.base.features);
            assert_eq!(a.base.features, orig.base.features);
            assert_eq!(a.base.speedup, b.base.speedup);
            assert_eq!(a.base.speedup, orig.base.speedup);
            assert_eq!(a.best_wg, b.best_wg);
            assert_eq!(a.best_wg, orig.best_wg);
            sentinels += (schema == Schema::V2 && a.best_wg.is_none()) as usize;
        }
        if schema == Schema::V2 {
            assert!(sentinels > 0, "no (0,0) sentinel rows exercised");
        }
        std::fs::remove_dir_all(&base).ok();
    }
}

#[test]
fn corrupt_binary_shards_are_typed_errors_not_panics() {
    let dir = tmpdir("corrupt");
    let mut s =
        ShardedSink::create(&dir, 1, "k20", Schema::V1, ShardFormat::Bin).unwrap();
    for i in 0..100 {
        s.accept(&record(i, Schema::V1)).unwrap();
    }
    s.finish().unwrap();
    let path = sink::shard_path_for(&dir, 0, ShardFormat::Bin);
    let bytes = std::fs::read(&path).unwrap();

    // Truncated mid-block: typed CorruptShard, recoverable downcast.
    std::fs::write(&path, &bytes[..bytes.len() - 23]).unwrap();
    let err = sink::load_sharded(&dir).unwrap_err();
    assert!(err.downcast_ref::<CorruptShard>().is_some(), "{err:#}");
    assert!(format!("{err:#}").contains("truncated"), "{err:#}");

    // One flipped payload bit: the FNV checksum catches it at EOF.
    let mut flipped = bytes.clone();
    let n = flipped.len();
    flipped[n - 1] ^= 0x40;
    std::fs::write(&path, &flipped).unwrap();
    let err = sink::load_sharded(&dir).unwrap_err();
    assert!(err.downcast_ref::<CorruptShard>().is_some(), "{err:#}");
    assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn non_finite_labels_are_rejected_on_load() {
    let dir = tmpdir("nanlabel");
    std::fs::create_dir_all(&dir).unwrap();
    let path = sink::shard_path_for(&dir, 0, ShardFormat::Bin);
    let mut w = BinShardWriter::create(&path, "m2090", Schema::V2).unwrap();
    let mut row = vec![1.0; Schema::V2.columns()];
    row[NUM_FEATURES] = 2.0;
    row[NUM_FEATURES + 1] = f64::NAN;
    row[NUM_FEATURES + 2] = 4.0;
    w.write_row(&row).unwrap();
    w.finish().unwrap();
    // The shard is structurally sound (checksum passes); the *label*
    // plane is garbage, and the record layer refuses it.
    let err = sink::load_sharded(&dir).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("workgroup label"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn format_detection_flags_mixed_directories() {
    let dir = tmpdir("mixed");
    let mut s =
        ShardedSink::create(&dir, 2, "m2090", Schema::V1, ShardFormat::Csv).unwrap();
    for i in 0..10 {
        s.accept(&record(i, Schema::V1)).unwrap();
    }
    s.finish().unwrap();
    // Overwrite shard 1 with *binary* content under the .csv name:
    // detection trusts the bytes, not the extension.
    let path = sink::shard_path_for(&dir, 1, ShardFormat::Csv);
    let mut w = BinShardWriter::create(&path, "m2090", Schema::V1).unwrap();
    w.write_row(&record(1, Schema::V1).csv_row(Schema::V1)).unwrap();
    w.finish().unwrap();
    let err = sink::load_sharded(&dir).unwrap_err();
    let mm = err
        .downcast_ref::<FormatMismatch>()
        .unwrap_or_else(|| panic!("expected FormatMismatch, got {err:#}"));
    assert_eq!(mm.expected, ShardFormat::Csv);
    assert_eq!(mm.found, ShardFormat::Bin);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pipeline_stage_counters_are_stable_across_thread_counts() {
    let dev = DeviceSpec::m2090();
    let mut rng = Rng::new(0x5EED);
    let templates = generator::generate(&mut rng, 0.02);
    let sweep = LaunchSweep::new(2048, 2048);
    let spec = PipelineSpec { validate: true, dedup: true };

    let mut reference: Option<(usize, Vec<(String, u64, u64, u64)>)> = None;
    for threads in [1usize, 2, 4] {
        let cfg = BuildConfig {
            configs_per_kernel: 4,
            measure: MeasureConfig::deterministic(),
            seed: 0xDA7A,
            threads,
            ..BuildConfig::default()
        };
        let mut staged =
            StagedSink::new(MemorySink::new(), spec.build(Schema::V1));
        let summary = dataset::build_streaming(
            &templates, &sweep, &dev, &cfg, &mut staged, None,
        )
        .unwrap();
        let counters = staged.counters();
        assert_eq!(counters.len(), 2);
        assert_eq!(counters[0].name, "validate");
        assert_eq!(counters[1].name, "dedup");
        // conservation at every stage boundary
        assert_eq!(counters[0].seen, summary.records);
        assert_eq!(
            counters[0].seen - counters[0].dropped,
            counters[1].seen
        );
        let kept = staged.inner().records.len();
        assert_eq!(
            kept as u64,
            counters[1].seen - counters[1].dropped
        );
        let digest: Vec<(String, u64, u64, u64)> = counters
            .iter()
            .map(|c| (c.name.clone(), c.seen, c.kept, c.dropped))
            .collect();
        match &reference {
            None => reference = Some((kept, digest)),
            Some((k0, d0)) => {
                // the stage pipeline is deterministic: identical tallies
                // and surviving stream at any parallelism
                assert_eq!(kept, *k0, "threads={threads}");
                assert_eq!(&digest, d0, "threads={threads}");
            }
        }
    }
}
