//! Frontend golden suite: the kernel-source frontend must reproduce the
//! hand-mapped `workloads/` descriptors from real OpenCL C fixtures,
//! malformed input must yield typed positioned errors (never panics),
//! and extraction invariants must hold under randomized launches.
//!
//! Reconciliation contract (documented in DESIGN.md §2d): every
//! descriptor field is matched exactly except
//!   * `comp_ilb` (+-1)  — the hand model charges mul+add separately
//!     where the frontend counts fused FMA-equivalents (matrixMul);
//!   * `comp_ep`  (+-2)  — ditto for the writeback epilogue;
//!   * `base_regs` (+-8) — the frontend's register estimate is a
//!     documented heuristic, not a compiler.

use lmtuner::frontend::extract::{extract_descriptor, ExtractErrorKind};
use lmtuner::frontend::{self, parse_program, AnalyzeOptions, Bindings, FrontendError};
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::kernelmodel::descriptor::KernelDescriptor;
use lmtuner::kernelmodel::features::{extract as features_of, FEATURE_NAMES, NUM_FEATURES};
use lmtuner::kernelmodel::launch::{GridGeom, Launch, WgGeom};
use lmtuner::util::prop;
use lmtuner::workloads;

fn fixture(name: &str) -> String {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures")
        .join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

fn opts(target: &str, launch: Launch, bindings: Bindings) -> AnalyzeOptions {
    AnalyzeOptions { target: target.into(), kernel: None, launch, bindings }
}

/// Per-feature reconciliation tolerances, in canonical feature order
/// (zero = exact).
fn tolerances() -> [f64; NUM_FEATURES] {
    let mut tol = [0.0; NUM_FEATURES];
    for (i, name) in FEATURE_NAMES.iter().enumerate() {
        tol[i] = match *name {
            "comp_ilb" => 1.0,
            "comp_ep" => 2.0,
            "regs" => 8.0,
            _ => 0.0,
        };
    }
    tol
}

/// Assert the extracted descriptor matches the hand-mapped one: exact on
/// the structural fields, documented tolerances on the rest, and the
/// 18-feature vectors agree under the same tolerances.
fn reconcile(extracted: &KernelDescriptor, hand: &KernelDescriptor) {
    let who = &hand.name;
    assert_eq!(extracted.taps, hand.taps, "{who}: taps");
    assert_eq!(extracted.inner_iters, hand.inner_iters, "{who}: inner_iters");
    assert_eq!(extracted.wus_per_wi, hand.wus_per_wi, "{who}: wus_per_wi");
    assert_eq!(extracted.region_rows, hand.region_rows, "{who}: region_rows");
    assert_eq!(extracted.region_cols, hand.region_cols, "{who}: region_cols");
    assert_eq!(extracted.offset_bounds, hand.offset_bounds, "{who}: offset_bounds");
    assert_eq!(extracted.launch, hand.launch, "{who}: launch");
    assert_eq!(extracted.elem_bytes, hand.elem_bytes, "{who}: elem_bytes");
    assert_eq!(
        (extracted.coal_ilb, extracted.coal_ep, extracted.uncoal_ilb, extracted.uncoal_ep),
        (hand.coal_ilb, hand.coal_ep, hand.uncoal_ilb, hand.uncoal_ep),
        "{who}: context access counts"
    );
    assert!(
        (extracted.tx_per_target_access - hand.tx_per_target_access).abs() < 1e-9,
        "{who}: tx/access {} vs {}",
        extracted.tx_per_target_access,
        hand.tx_per_target_access
    );
    assert!(
        (extracted.reuse - hand.reuse).abs() < 1e-9,
        "{who}: reuse {} vs {}",
        extracted.reuse,
        hand.reuse
    );
    let fe = features_of(extracted);
    let fh = features_of(hand);
    let tol = tolerances();
    for i in 0..NUM_FEATURES {
        assert!(
            (fe[i] - fh[i]).abs() <= tol[i] + 1e-9,
            "{who}: feature `{}` extracted {} vs hand {} (tolerance {})",
            FEATURE_NAMES[i],
            fe[i],
            fh[i],
            tol[i]
        );
    }
}

/// Hand-mapped instances of one Table 3 benchmark, by instance name.
fn hand_instances(
    bench: &str,
    dev: &DeviceSpec,
) -> std::collections::HashMap<String, KernelDescriptor> {
    let b = workloads::all()
        .into_iter()
        .find(|b| b.name == bench)
        .unwrap_or_else(|| panic!("no Table 3 row named {bench}"));
    (b.instances)(dev).into_iter().map(|d| (d.name.clone(), d)).collect()
}

// Sweeps mirrored from the workloads modules; the by-name lookup fails
// loudly if either side drifts.
const CONV_RADII: [u32; 5] = [1, 2, 3, 4, 6];
const CONV_WGS: [(u32, u32); 5] = [(16, 4), (16, 16), (32, 4), (32, 8), (64, 4)];
const CONV_SIZES: [u32; 4] = [256, 512, 1024, 2048];
const CONV_RPT: [u32; 3] = [1, 2, 4];

#[test]
fn golden_convolution_matches_hand_mapping() {
    let dev = DeviceSpec::m2090();
    let hand = hand_instances("convolution", &dev);
    let mut checked = 0usize;
    for pass in ["row", "col"] {
        let prog = parse_program(&fixture(&format!("convolution_{pass}.cl"))).unwrap();
        for &r in &CONV_RADII {
            for &wg in &CONV_WGS {
                for &size in &CONV_SIZES {
                    for &rpt in &CONV_RPT {
                        let launch = workloads::launch_over(wg, (size, size / rpt));
                        let b = Bindings::new()
                            .set("width", size as i64)
                            .set("rows_per_thread", rpt as i64)
                            .set("radius", r as i64);
                        let d = extract_descriptor(&prog, &opts("input", launch, b), &dev)
                            .unwrap_or_else(|e| panic!("{pass} r{r} {size} rpt{rpt}: {e}"));
                        let name = format!(
                            "convolution_{pass}_r{r}_wg{}x{}_{size}_rpt{rpt}",
                            wg.0, wg.1
                        );
                        let h = hand.get(&name).unwrap_or_else(|| panic!("no {name}"));
                        reconcile(&d, h);
                        checked += 1;
                    }
                }
            }
        }
    }
    assert_eq!(checked, 600, "must cover every Table 3 convolution instance");
}

const MM_SIZES: [u32; 2] = [512, 1024];
const MM_TILE_K: [u32; 3] = [4, 8, 16];
const MM_WGS: [(u32, u32); 11] = [
    (16, 4),
    (16, 8),
    (16, 16),
    (32, 2),
    (32, 4),
    (32, 8),
    (32, 16),
    (8, 8),
    (8, 16),
    (64, 2),
    (64, 4),
];

#[test]
fn golden_matrixmul_matches_hand_mapping() {
    // The hand mapping sweeps an unroll factor the source expresses only
    // through its FMA accounting (comp_ilb = 2u); the fixture is the
    // canonical u=1 kernel, reconciled against every u=1 instance.
    let dev = DeviceSpec::m2090();
    let hand = hand_instances("matrixMul", &dev);
    let prog = parse_program(&fixture("matrixmul.cl")).unwrap();
    let mut checked = 0usize;
    for &size in &MM_SIZES {
        for &tk in &MM_TILE_K {
            for &wg in &MM_WGS {
                let launch = workloads::launch_over(wg, (size, size));
                let b = Bindings::new().set("size", size as i64).set("tile_k", tk as i64);
                let d = extract_descriptor(&prog, &opts("b", launch, b), &dev)
                    .unwrap_or_else(|e| panic!("mm {size} k{tk}: {e}"));
                let name = format!("matrixMul_{size}_k{tk}_wg{}x{}_u1", wg.0, wg.1);
                let h = hand.get(&name).unwrap_or_else(|| panic!("no {name}"));
                reconcile(&d, h);
                checked += 1;
            }
        }
    }
    assert_eq!(checked, 66);
}

const TR_WGS: [(u32, u32); 7] =
    [(8, 8), (16, 8), (16, 16), (32, 8), (32, 16), (32, 32), (64, 4)];
const TR_SIZES: [u32; 3] = [512, 1024, 2048];

#[test]
fn golden_transpose_matches_hand_mapping() {
    let dev = DeviceSpec::m2090();
    let hand = hand_instances("transpose", &dev);
    let prog = parse_program(&fixture("transpose.cl")).unwrap();
    let mut checked = 0usize;
    for &size in &TR_SIZES {
        for &wg in &TR_WGS {
            let launch = workloads::launch_over(wg, (size, size));
            let b = Bindings::new().set("width", size as i64).set("height", size as i64);
            let d = extract_descriptor(&prog, &opts("output", launch, b), &dev)
                .unwrap_or_else(|e| panic!("transpose {size}: {e}"));
            let name = format!("transpose_{size}_wg{}x{}", wg.0, wg.1);
            let h = hand.get(&name).unwrap_or_else(|| panic!("no {name}"));
            reconcile(&d, h);
            checked += 1;
        }
    }
    assert_eq!(checked, 21, "must cover every Table 3 transpose instance");
}

#[test]
fn golden_descriptors_port_across_the_device_registry() {
    // The same source reconciles on every registered device (the hand
    // mapping is device-parametric through DescriptorBuilder).
    use lmtuner::gpu::registry;
    for dev in registry::all() {
        let hand = hand_instances("transpose", &dev);
        let prog = parse_program(&fixture("transpose.cl")).unwrap();
        let launch = workloads::launch_over((16, 16), (1024, 1024));
        let b = Bindings::new().set("width", 1024).set("height", 1024);
        let d = extract_descriptor(&prog, &opts("output", launch, b), &dev).unwrap();
        reconcile(&d, &hand["transpose_1024_wg16x16"]);
    }
}

// ---------------------------------------------------------------------
// Typed, positioned errors on malformed / unsupported input.

fn default_launch() -> Launch {
    Launch::new(WgGeom { w: 16, h: 16 }, GridGeom { w: 512, h: 512 })
}

fn analyze_str(
    src: &str,
    target: &str,
    bindings: Bindings,
) -> Result<KernelDescriptor, FrontendError> {
    frontend::analyze(src, &opts(target, default_launch(), bindings), &DeviceSpec::m2090())
}

#[test]
fn malformed_sources_give_typed_positioned_errors() {
    // Lex error.
    let e = analyze_str("__kernel void f€", "x", Bindings::new()).unwrap_err();
    assert!(matches!(e, FrontendError::Lex(_)), "{e}");
    // Parse error with position.
    let e = analyze_str(
        "__kernel void f(__global float* a) {\n    a[0] = ;\n}",
        "a",
        Bindings::new(),
    )
    .unwrap_err();
    match &e {
        FrontendError::Parse(p) => assert_eq!(p.pos.line, 2, "{p}"),
        other => panic!("expected parse error, got {other}"),
    }
    // Unterminated block.
    let e = analyze_str("__kernel void f(__global float* a) { a[0] = 1.0f;", "a", Bindings::new())
        .unwrap_err();
    assert!(e.to_string().contains("unterminated"), "{e}");
}

#[test]
fn analysis_errors_are_typed_and_name_the_problem() {
    let src = fixture("transpose.cl");
    let dev = DeviceSpec::m2090();
    let launch = default_launch();

    // Unknown target array lists the alternatives.
    let e = frontend::analyze(&src, &opts("nosuch", launch, Bindings::new()), &dev).unwrap_err();
    match &e {
        FrontendError::Extract(x) => {
            assert!(matches!(x.kind, ExtractErrorKind::UnknownArray { .. }), "{x}");
            assert!(x.to_string().contains("input"), "{x}");
        }
        other => panic!("expected extract error, got {other}"),
    }

    // Unbound scalar argument names the missing --set.
    let e = frontend::analyze(&src, &opts("output", launch, Bindings::new()), &dev).unwrap_err();
    assert!(e.to_string().contains("--set"), "{e}");

    // Invalid launch (wg does not divide grid).
    let bad = Launch::new(WgGeom { w: 48, h: 16 }, GridGeom { w: 512, h: 512 });
    let b = Bindings::new().set("width", 512).set("height", 512);
    let e = frontend::analyze(&src, &opts("output", bad, b), &dev).unwrap_err();
    assert!(e.to_string().contains("launch"), "{e}");
}

#[test]
fn unsupported_constructs_are_typed_errors() {
    // Non-affine subscript.
    let src = "__kernel void f(__global float* a) {\n    int x = get_global_id(0);\n    \
               a[x * x] = 1.0f;\n}";
    let e = analyze_str(src, "a", Bindings::new()).unwrap_err();
    assert!(e.to_string().contains("affine"), "{e}");
    assert_eq!(e.pos().line, 3, "{e}");

    // Kernel that already stages into __local memory.
    let e = analyze_str(
        "__kernel void f(__global float* a, __local float* tile) {\n    tile[0] = a[0];\n}",
        "a",
        Bindings::new(),
    )
    .unwrap_err();
    assert!(e.to_string().contains("__local"), "{e}");

    // Preprocessor use points at --set.
    let src = "#define R 4\n__kernel void f(__global float* a) { a[0] = 1.0f; }";
    let e = analyze_str(src, "a", Bindings::new()).unwrap_err();
    assert!(e.to_string().contains("--set"), "{e}");

    // Zero-step loop.
    let src = "__kernel void f(__global float* a) {\n    \
               for (int i = 0; i < 4; i += 0) { a[i] = 1.0f; }\n}";
    let e = analyze_str(src, "a", Bindings::new()).unwrap_err();
    assert!(e.to_string().contains("zero step"), "{e}");

    // i64::MIN / -1 in constant folding is a typed overflow error, not
    // an arithmetic abort (division overflow panics even in release).
    let src = "__kernel void f(__global float* a) {\n    \
               int v = (0 - 9223372036854775807 - 1) / (0 - 1);\n    a[v] = 1.0f;\n}";
    let e = analyze_str(src, "a", Bindings::new()).unwrap_err();
    assert!(e.to_string().contains("overflow"), "{e}");

    // Unqualified pointer parameters are invalid OpenCL — refuse to
    // guess which memory they alias.
    let src = "__kernel void f(float* a) { a[0] = 1.0f; }";
    let e = analyze_str(src, "a", Bindings::new()).unwrap_err();
    assert!(e.to_string().contains("unqualified pointer"), "{e}");

    // Target never accessed.
    let e = analyze_str(
        "__kernel void f(__global float* a, __global float* b) { a[0] = 1.0f; }",
        "b",
        Bindings::new(),
    )
    .unwrap_err();
    assert!(e.to_string().contains("never subscripted"), "{e}");
}

// ---------------------------------------------------------------------
// Property tests (util::prop): extraction invariants.

/// Launch/parameter draws valid for the convolution fixtures.
fn draw_conv_case(rng: &mut lmtuner::util::prng::Rng) -> (Launch, Bindings, u32) {
    let wgs = [(8u32, 8u32), (16, 4), (16, 16), (32, 8), (32, 32), (64, 4)];
    let sizes = [256u32, 512, 1024, 2048];
    let rpts = [1u32, 2, 4];
    let wg = wgs[rng.below(wgs.len() as u64) as usize];
    let size = sizes[rng.below(sizes.len() as u64) as usize];
    let rpt = rpts[rng.below(rpts.len() as u64) as usize];
    let r = rng.below(7) as u32; // radius 0..6, including the degenerate 0
    let launch = workloads::launch_over(wg, (size, size / rpt));
    let b = Bindings::new()
        .set("width", size as i64)
        .set("rows_per_thread", rpt as i64)
        .set("radius", r as i64);
    (launch, b, r)
}

#[test]
fn prop_extracted_features_are_finite_and_sane() {
    let row = parse_program(&fixture("convolution_row.cl")).unwrap();
    let col = parse_program(&fixture("convolution_col.cl")).unwrap();
    let devices = lmtuner::gpu::registry::all();
    prop::check("frontend-invariants", 192, |rng| {
        let (launch, b, _r) = draw_conv_case(rng);
        let dev = &devices[rng.below(devices.len() as u64) as usize];
        let prog = if rng.below(2) == 0 { &row } else { &col };
        let d = match extract_descriptor(prog, &opts("input", launch, b), dev) {
            Ok(d) => d,
            Err(e) => return Err(format!("extraction failed: {e}")),
        };
        let f = features_of(&d);
        lmtuner::prop_assert!(f.iter().all(|x| x.is_finite()), "non-finite features {f:?}");
        let (r0, r1, c0, c1) = d.offset_bounds;
        lmtuner::prop_assert!(r1 >= r0 && c1 >= c0, "negative offset span {:?}", d.offset_bounds);
        lmtuner::prop_assert!(d.region_rows >= 1, "region_rows {}", d.region_rows);
        lmtuner::prop_assert!(d.region_cols >= 1, "region_cols {}", d.region_cols);
        lmtuner::prop_assert!(d.taps >= 1, "taps {}", d.taps);
        lmtuner::prop_assert!(d.reuse > 0.0, "reuse {}", d.reuse);
        lmtuner::prop_assert!(d.tx_per_target_access >= 1.0, "tx {}", d.tx_per_target_access);
        Ok(())
    });
}

#[test]
fn prop_pretty_print_roundtrip_preserves_descriptor() {
    let dev = DeviceSpec::m2090();
    let fixtures = [
        ("convolution_row.cl", "input"),
        ("convolution_col.cl", "input"),
        ("matrixmul.cl", "b"),
        ("transpose.cl", "output"),
    ];
    let progs: Vec<_> = fixtures
        .iter()
        .map(|(f, t)| (parse_program(&fixture(f)).unwrap(), *t))
        .collect();
    prop::check("frontend-roundtrip", 96, |rng| {
        let (prog, target) = &progs[rng.below(progs.len() as u64) as usize];
        let (launch, b, _r) = draw_conv_case(rng);
        let b = b.set("size", 512).set("tile_k", 8).set("height", 512);
        let o = opts(target, launch, b);
        let direct = extract_descriptor(prog, &o, &dev);
        let printed = prog.to_string();
        let reparsed = match parse_program(&printed) {
            Ok(p) => p,
            Err(e) => {
                return Err(format!("pretty-printed source failed to reparse: {e}\n{printed}"))
            }
        };
        let roundtrip = extract_descriptor(&reparsed, &o, &dev);
        match (direct, roundtrip) {
            (Ok(a), Ok(b)) => {
                lmtuner::prop_assert!(a == b, "descriptor changed across pretty-print round trip");
            }
            (Err(_), Err(_)) => {
                // Both sides reject: fine. Positions differ between the
                // original and the canonical print, so messages may too.
            }
            (a, b) => {
                return Err(format!(
                    "round trip flipped outcome: {:?} vs {:?}",
                    a.map(|d| d.name),
                    b.map(|d| d.name)
                ))
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// End-to-end: extracted features flow into a trained forest.

#[test]
fn extracted_features_drive_the_runtime_executor() {
    use lmtuner::runtime::executor::{BatchExecutor, NativeForestExecutor};
    let dev = DeviceSpec::m2090();
    let prog = parse_program(&fixture("transpose.cl")).unwrap();
    let launch = workloads::launch_over((16, 16), (1024, 1024));
    let b = Bindings::new().set("width", 1024).set("height", 1024);
    let d = extract_descriptor(&prog, &opts("output", launch, b), &dev).unwrap();
    let feats = features_of(&d);

    // Tiny forest trained on a small synthetic population.
    let mut rng = lmtuner::util::prng::Rng::new(7);
    let templates = lmtuner::synth::generator::generate_n(&mut rng, 1);
    let sweep = lmtuner::synth::sweep::LaunchSweep::new(2048, 2048);
    let cfg = lmtuner::synth::dataset::BuildConfig { configs_per_kernel: 2, ..Default::default() };
    let records = lmtuner::synth::dataset::build(&templates, &sweep, &dev, &cfg);
    let forest = lmtuner::ml::forest::Forest::fit_tune_records(
        &records,
        &lmtuner::ml::forest::ForestConfig { num_trees: 3, ..Default::default() },
    )
    .expect("simulator records are finite");
    let enc = lmtuner::ml::export::encode(&forest, lmtuner::ml::export::ExportContract::default());
    let exec = NativeForestExecutor::new(enc);
    let scores = exec.predict(&[feats.to_vec()]).unwrap();
    assert_eq!(scores.len(), 1);
    assert!(scores[0].is_finite());
    assert_eq!(scores[0], forest.predict(&feats));
}
