/*
 * Column-pass 2D separable convolution (NVIDIA SDK shape, paper
 * Table 3). Same work decomposition as the row pass, but the
 * (2*radius + 1) taps run vertically: the stencil offsets land in the
 * row coordinate, so the staged region grows a row apron instead of a
 * column apron. Every access is still warp-coalesced.
 *
 * Analyze with:
 *   lmtuner analyze convolution_col.cl --array input \
 *       --set width=512,rows_per_thread=1,radius=2 --wg 16x16 --grid 512x512
 */
__kernel void convolution_col(__global const float* input,
                              __global float* output,
                              __constant float* coeff,
                              int width,
                              int rows_per_thread,
                              int radius,
                              float norm) {
    int gx = get_global_id(0);
    int gy = get_global_id(1);
    for (int p = 0; p < rows_per_thread; p++) {
        float sum = 0.0f;
        for (int k = -radius; k <= radius; k++) {
            sum += input[(gy + p * get_global_size(1) + k) * width + gx] * coeff[k + radius];
        }
        output[(gy + p * get_global_size(1)) * width + gx] = sum * norm;
    }
}
