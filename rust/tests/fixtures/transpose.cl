/*
 * Matrix transpose out[x][y] = in[y][x] (NVIDIA SDK shape, paper
 * Table 3). The read is coalesced; the write scatters one row per
 * x-lane — the canonical coalescing-fix candidate for local-memory
 * staging. No data reuse at all.
 *
 * Analyze with:
 *   lmtuner analyze transpose.cl --array output \
 *       --set width=1024,height=1024 --wg 16x16 --grid 1024x1024
 */
__kernel void transpose(__global const float* input,
                        __global float* output,
                        int width,
                        int height) {
    int x = get_global_id(0);
    int y = get_global_id(1);
    int idx_in = y * width + x;
    int idx_out = x * height + y;
    output[idx_out] = input[idx_in];
}
