/*
 * Matrix multiply C = A x B (NVIDIA SDK shape, paper Table 3).
 *
 * One work item per C element. The k dimension is processed in
 * `tile_k`-sized rounds: per round the workgroup touches a
 * tile_k x wg_w block of B (the staging candidate — every element is
 * reused by the workgroup's wg_h rows), while the A read broadcasts
 * across the row and the C store is the coalesced epilogue.
 *
 * Analyze with:
 *   lmtuner analyze matrixmul.cl --array b \
 *       --set size=512,tile_k=8 --wg 16x8 --grid 512x512
 */
__kernel void matrixmul(__global const float* a,
                        __global const float* b,
                        __global float* c,
                        int size,
                        int tile_k) {
    int gx = get_global_id(0);
    int gy = get_global_id(1);
    float sum = 0.0f;
    for (int t = 0; t < size / tile_k; t++) {
        for (int k = 0; k < tile_k; k++) {
            sum += a[gy * size + t * tile_k + k] * b[(t * tile_k + k) * size + gx];
        }
    }
    c[gy * size + gx] = sum;
}
