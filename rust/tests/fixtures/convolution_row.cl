/*
 * Row-pass 2D separable convolution (NVIDIA SDK shape, paper Table 3).
 *
 * Each work item produces `rows_per_thread` output rows, distributed
 * cyclically over the grid's y extent (paper §4.1); per row it reads a
 * (2*radius + 1)-tap horizontal stencil of `input` and writes one
 * coalesced output element. `coeff` lives in __constant space (constant
 * cache), so the only DRAM context access is the output store.
 *
 * Analyze with:
 *   lmtuner analyze convolution_row.cl --array input \
 *       --set width=512,rows_per_thread=1,radius=2 --wg 16x16 --grid 512x512
 */
__kernel void convolution_row(__global const float* input,
                              __global float* output,
                              __constant float* coeff,
                              int width,
                              int rows_per_thread,
                              int radius,
                              float norm) {
    int gx = get_global_id(0);
    int gy = get_global_id(1);
    for (int p = 0; p < rows_per_thread; p++) {
        float sum = 0.0f;
        for (int k = -radius; k <= radius; k++) {
            sum += input[(gy + p * get_global_size(1)) * width + gx + k] * coeff[k + radius];
        }
        output[(gy + p * get_global_size(1)) * width + gx] = sum * norm;
    }
}
