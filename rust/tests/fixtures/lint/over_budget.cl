/*
 * Seeded defect: the whole 4096-row column of `b` is reused by every
 * work item, so the staged region is 4096 x 16 x 4 B = 256 KB — far
 * over the 48 KB per-workgroup local-memory budget of every device in
 * the registry.
 *
 * Expected: LM003 (warn, via the staging certificate) for `b`,
 * nothing else in the deny/warn sets.
 *   lmtuner lint over_budget.cl --set size=512 --wg 16x16 --grid 512x512
 */
__kernel void over_budget(__global const float* b,
                          __global float* out,
                          int size) {
    int gx = get_global_id(0);
    float sum = 0.0f;
    for (int k = 0; k < 4096; k++) {
        sum += b[k * size + gx];
    }
    out[gx] = sum;
}
