/*
 * Seeded defect: barrier() under work-item-divergent control flow.
 * Only the first four x-lanes of each workgroup reach the barrier, so
 * the rest of the group hangs (or worse) on real hardware.
 *
 * Expected: LM001 (deny) on the barrier line, nothing else.
 *   lmtuner lint divergent_barrier.cl --set width=512 --wg 16x16 --grid 512x512
 */
__kernel void divergent_barrier(__global const float* in,
                                __global float* out,
                                int width) {
    int gx = get_global_id(0);
    int gy = get_global_id(1);
    float v = in[gy * width + gx];
    if (get_local_id(0) < 4) {
        barrier(1);
    }
    out[gy * width + gx] = v;
}
