/*
 * Seeded defect: a 600-tap horizontal stencil walk on a 512-wide image.
 * The tap offsets span 0..599 — past a full row stride — so the
 * flattened index wraps into the next row; no host-side apron
 * allocation can make this access mean what it says.
 *
 * Expected: LM002 (deny) on the in[] load, nothing else.
 *   lmtuner lint oob_tap.cl --set width=512 --wg 16x16 --grid 512x512
 */
__kernel void oob_tap(__global const float* in,
                      __global float* out,
                      int width) {
    int gx = get_global_id(0);
    int gy = get_global_id(1);
    float sum = 0.0f;
    for (int k = 0; k < 600; k++) {
        sum += in[gy * width + gx + k];
    }
    out[gy * width + gx] = sum;
}
