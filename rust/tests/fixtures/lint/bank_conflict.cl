/*
 * Seeded defect: a column walk whose x-lane stride is 32 elements —
 * a multiple of the 32 shared-memory banks. Staging this array as-is
 * would serialize every warp access, and the extractor's +1-column pad
 * does not apply (the row does not depend on the x lane).
 *
 * Expected: LM004 (warn) on the out[] store, nothing else (the
 * uncoalesced-access lint LM005 is suppressed where LM004 fires).
 *   lmtuner lint bank_conflict.cl --set width=512 --wg 16x16 --grid 512x512
 */
__kernel void bank_conflict(__global const float* in,
                            __global float* out,
                            int width) {
    int gx = get_global_id(0);
    int gy = get_global_id(1);
    out[gy * width + gx * 32] = in[gy * width + gx];
}
