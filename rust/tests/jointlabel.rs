//! Integration tests for the joint (schema v2) label plane: argmax-wg
//! determinism under the balanced launch sampler, label sensitivity
//! across the device portfolio, and v1 -> v2 up-conversion through the
//! sharded persistence layer.

use lmtuner::gpu::registry;
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::sim::exec::{MeasureConfig, Schema, TuneRecord};
use lmtuner::synth::sink::{self, RecordSink, ShardedCsvSink};
use lmtuner::synth::sweep::{argmax_wg, LaunchSweep};
use lmtuner::synth::{dataset, generator};
use lmtuner::util::prng::Rng;

fn tmpdir(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lmtuner-joint-{name}-{}", std::process::id()))
}

fn build_on(dev: &DeviceSpec, tuples: usize, configs: usize) -> Vec<TuneRecord> {
    let mut rng = Rng::new(0x10B7);
    let templates = generator::generate_n(&mut rng, tuples);
    let sweep = LaunchSweep::new(2048, 2048);
    let cfg = dataset::BuildConfig {
        configs_per_kernel: configs,
        measure: MeasureConfig::deterministic(),
        ..Default::default()
    };
    dataset::build(&templates, &sweep, dev, &cfg)
}

#[test]
fn argmax_labels_are_deterministic_under_sampled_balanced() {
    // The joint label rides on `sampled_balanced`'s launch draw; the
    // whole path (sampler -> simulate -> argmax) must reproduce exactly,
    // and the parallel build must agree with the serial reference.
    let dev = DeviceSpec::m2090();
    let a = build_on(&dev, 2, 6);
    let b = build_on(&dev, 2, 6);
    assert!(a.len() > 1000, "{} records", a.len());
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.base.name, y.base.name);
        assert_eq!(x.best_wg, y.best_wg);
    }
    // Every emitted label is a valid pow2 launch shape; v2 is lossless.
    for r in &a {
        let (w, h) = r.best_wg.expect("generated records carry the label");
        assert!(w.is_power_of_two() && h.is_power_of_two());
        assert!(w as u64 * h as u64 <= 1024, "{w}x{h}");
        assert_eq!(r.schema(), Schema::V2);
    }

    // Tie-breaking: argmax_wg must not depend on sweep arrival order.
    let sweep = LaunchSweep::new(2048, 2048);
    let launches = sweep.all();
    let timed: Vec<_> = launches
        .iter()
        .enumerate()
        // Coarse quantization manufactures plenty of exact ties.
        .map(|(i, l)| (*l, 1.0 + (i % 3) as f64))
        .collect();
    let forward = argmax_wg(&timed).expect("finite times");
    let mut reversed = timed.clone();
    reversed.reverse();
    assert_eq!(argmax_wg(&reversed), Some(forward), "order-dependent tie-break");
    // Non-finite times never win (and an all-NaN sweep has no label).
    let nan_best: Vec<_> =
        timed.iter().map(|(l, t)| (*l, if *t == 1.0 { f64::NAN } else { *t })).collect();
    if let Some(wg) = argmax_wg(&nan_best) {
        let winner = nan_best
            .iter()
            .filter(|(_, t)| t.is_finite())
            .any(|(l, _)| (l.wg.w, l.wg.h) == wg);
        assert!(winner, "label came from a NaN-timed launch");
    }
    assert_eq!(argmax_wg(&[(launches[0], f64::NAN)]), None);
}

#[test]
fn joint_labels_flip_across_the_device_portfolio() {
    // The same synthetic population, measured on each registered
    // testbed: if the argmax workgroup never changed with the device,
    // the joint label would carry no cross-device signal and the v2
    // schema would be dead weight.
    let devices = registry::all();
    assert!(devices.len() >= 4, "portfolio shrank to {}", devices.len());
    let mut label_sets: Vec<Vec<Option<(u32, u32)>>> = Vec::new();
    for dev in &devices {
        let recs = build_on(dev, 1, 4);
        assert!(!recs.is_empty());
        label_sets.push(recs.iter().map(|r| r.best_wg).collect());
    }
    for s in &label_sets[1..] {
        assert_eq!(s.len(), label_sets[0].len(), "record streams diverged");
    }
    let mut flips = 0usize;
    for other in &label_sets[1..] {
        flips += label_sets[0]
            .iter()
            .zip(other)
            .filter(|(a, b)| a != b)
            .count();
    }
    assert!(
        flips > 0,
        "argmax workgroup identical across all {} devices — label carries \
         no device signal",
        devices.len()
    );
}

#[test]
fn v1_shards_up_convert_and_round_trip_through_v2() {
    // A pre-joint (v1) shard directory loads as unlabeled TuneRecords,
    // and re-persisting under v2 writes the 0,0 sentinel that reads
    // back as None — features and speedups byte-stable throughout.
    let dev = DeviceSpec::m2090();
    let records = build_on(&dev, 1, 3);

    // Write v1 shards: the joint label is dropped on disk.
    let dir_v1 = tmpdir("v1");
    let mut sink = ShardedCsvSink::create(&dir_v1, 2, dev.key).unwrap();
    for r in &records {
        sink.accept(r).unwrap();
    }
    sink.finish().unwrap();
    let (back, stream) = sink::load_sharded_tagged(&dir_v1).unwrap();
    assert_eq!(stream.schema, Schema::V1);
    assert_eq!(back.len(), records.len());
    for (a, b) in back.iter().zip(&records) {
        assert_eq!(a.best_wg, None, "v1 shards fabricated a label");
        assert_eq!(a.base.features, b.base.features);
        assert!((a.base.speedup - b.base.speedup).abs() < 1e-9);
        assert_eq!(a.schema(), Schema::V1);
    }

    // Re-persist the up-converted records under v2: unlabeled rows
    // become the 0,0 sentinel and survive a reload as None.
    let dir_v2 = tmpdir("v2");
    let mut sink2 =
        ShardedCsvSink::create_schema(&dir_v2, 2, dev.key, Schema::V2).unwrap();
    for r in &back {
        sink2.accept(r).unwrap();
    }
    sink2.finish().unwrap();
    let shard0 = std::fs::read_to_string(sink::shard_path(&dir_v2, 0)).unwrap();
    assert!(shard0.contains("# schema=v2"), "v2 shard missing the stamp");
    let (again, stream2) = sink::load_sharded_tagged(&dir_v2).unwrap();
    assert_eq!(stream2.schema, Schema::V2);
    for (a, b) in again.iter().zip(&back) {
        assert_eq!(a.best_wg, None, "0,0 sentinel misread as a real label");
        assert_eq!(a.base.features, b.base.features);
        assert!((a.base.speedup - b.base.speedup).abs() < 1e-9);
    }

    // The labeled originals round-trip their labels through v2 too.
    let dir_v2b = tmpdir("v2b");
    let mut sink3 =
        ShardedCsvSink::create_schema(&dir_v2b, 3, dev.key, Schema::V2).unwrap();
    for r in &records {
        sink3.accept(r).unwrap();
    }
    sink3.finish().unwrap();
    let (labeled, _) = sink::load_sharded_tagged(&dir_v2b).unwrap();
    for (a, b) in labeled.iter().zip(&records) {
        assert_eq!(a.best_wg, b.best_wg);
    }

    for d in [&dir_v1, &dir_v2, &dir_v2b] {
        std::fs::remove_dir_all(d).ok();
    }
}
