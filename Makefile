# Convenience targets. Tier-1 verification needs only `build` + `test`
# (no artifacts, no network). `artifacts` requires a python with jax to
# AOT-lower the Pallas kernels to HLO text for the PJRT backend.

.PHONY: build test docs artifacts clean

build:
	cargo build --release

test:
	cargo test -q

# Same gate CI runs: doc rot fails the build.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p lmtuner

artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts

clean:
	cargo clean
	rm -rf artifacts
