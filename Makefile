# Convenience targets. Tier-1 verification needs only `build` + `test`
# (no artifacts, no network). `artifacts` requires a python with jax to
# AOT-lower the Pallas kernels to HLO text for the PJRT backend.

.PHONY: build test fmt-check clippy docs artifacts bench-snapshots clean

build:
	cargo build --release

test:
	cargo test -q

# Same format gate CI runs: the whole tree, vendor/ excluded as
# third-party.
fmt-check:
	rustfmt --edition 2021 --check $$(git ls-files '*.rs' ':!:vendor/*')

# Same clippy gate CI runs; the allowed style envelope lives in
# Cargo.toml [lints.clippy].
clippy:
	cargo clippy --all-targets -p lmtuner -- -D warnings

# Same gate CI runs: doc rot fails the build.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p lmtuner

artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts

# Seconds-scale smoke run of the perf benches; refreshes the committed
# BENCH_perf_inference.json / BENCH_perf_train.json /
# BENCH_perf_dataset.json snapshots at the repo root (same sections and
# JSON shape as a full run, fewer iterations — see EXPERIMENTS.md §Perf
# for publishable numbers).
bench-snapshots:
	LMTUNER_BENCH_SMOKE=1 cargo bench --bench perf_inference
	LMTUNER_BENCH_SMOKE=1 cargo bench --bench perf_train
	LMTUNER_BENCH_SMOKE=1 cargo bench --bench perf_dataset

clean:
	cargo clean
	rm -rf artifacts
