//! Vendored minimal subset of the `anyhow` crate.
//!
//! The build environment has no network access to crates.io, so the crate
//! graph must be self-contained. This shim implements exactly the surface
//! lmtuner uses — `Error`, `Result`, `Context`, `anyhow!`, `bail!`,
//! `ensure!`, `Error::new`, `downcast_ref` — with the same semantics for
//! that subset: context wrapping, source-chain capture on conversion,
//! `{}` printing the outermost message and `{:#}` the whole chain, and
//! typed recovery of the root error for errors built from a
//! `std::error::Error` value (the typed-error pattern `DeviceMismatch`
//! / `SchemaMismatch` / `ArityMismatch` / `CorruptShard` rely on).

use std::convert::Infallible;
use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus its chain of causes, and —
/// when built from a typed `std::error::Error` value — that root error
/// itself, recoverable via [`Error::downcast_ref`].
pub struct Error {
    /// `chain[0]` is the outermost message; each following entry is the
    /// cause of the one before it.
    chain: Vec<String>,
    /// The typed root error this value was converted from, if any.
    /// Context wrapping keeps it; `Error::msg` has none.
    typed: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message. The message is
    /// stringified, so there is no typed root to downcast to.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], typed: None }
    }

    /// Build an error from a typed `std::error::Error` value, keeping it
    /// recoverable via [`Error::downcast_ref`] (same as `From`).
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error::from(error)
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The typed root error, if this value was built from one (via `?`,
    /// `From`, or [`Error::new`]) and it is a `T`. Context layers do not
    /// hide it. Errors built from bare messages have no typed root.
    pub fn downcast_ref<T>(&self) -> Option<&T>
    where
        T: std::error::Error + 'static,
    {
        let typed = self.typed.as_deref()?;
        (typed as &(dyn std::error::Error + 'static)).downcast_ref::<T>()
    }

    /// The messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost first.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain, typed: Some(Box::new(e)) }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring the real crate.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a displayable value, or format
/// arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        let v = Some(3u32).with_context(|| "unused").unwrap();
        assert_eq!(v, 3);
    }

    #[test]
    fn macros_build_errors() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {}", flag);
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(inner(true).unwrap(), 7);
        let e = inner(false).unwrap_err();
        assert_eq!(format!("{e}"), "flag was false");
        let msg = anyhow!("x = {}", 4);
        assert_eq!(format!("{msg}"), "x = 4");
        let from_string = anyhow!(String::from("plain"));
        assert_eq!(format!("{from_string}"), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn run() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(run().is_err());
    }

    #[test]
    fn downcast_ref_recovers_the_typed_root() {
        let e: Error = io_err().into();
        let io = e.downcast_ref::<std::io::Error>().expect("typed root");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        // context layers keep the typed root reachable
        let wrapped = e.context("while probing");
        assert!(wrapped.downcast_ref::<std::io::Error>().is_some());
        // Error::new is the explicit form of From
        let e2 = Error::new(io_err());
        assert!(e2.downcast_ref::<std::io::Error>().is_some());
        // message-built errors have no typed root
        assert!(Error::msg("plain").downcast_ref::<std::io::Error>().is_none());
    }
}
