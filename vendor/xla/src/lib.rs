//! Pure-Rust stub of the `xla` (PJRT) FFI crate.
//!
//! The real crate links the XLA C++ runtime, which is not present in
//! this build environment. The stub keeps the whole call surface that
//! `lmtuner::runtime` compiles against — `Literal`, `PjRtClient`,
//! `PjRtLoadedExecutable`, `HloModuleProto`, `XlaComputation` — but
//! [`PjRtClient::cpu`] fails at runtime with a clear error, so every
//! caller hits one well-defined "PJRT unavailable" point and can fall
//! back to the native executor. `Literal` is a real little typed tensor
//! container (data + dims), so literal construction and readback behave
//! normally even in stub mode.

use std::fmt;

/// Error type mirroring the real crate's: displayable, `std::error`.
#[derive(Clone, Debug)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }

    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: the XLA/PJRT runtime is not linked into this build \
             (vendor/xla stub); use the native executor instead"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element storage for [`Literal`]. Public only so [`NativeType`] can
/// name it in its signatures; not part of the intended API.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Repr {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl Repr {
    fn len(&self) -> usize {
        match self {
            Repr::F32(v) => v.len(),
            Repr::F64(v) => v.len(),
            Repr::I32(v) => v.len(),
            Repr::I64(v) => v.len(),
        }
    }

    fn type_name(&self) -> &'static str {
        match self {
            Repr::F32(_) => "f32",
            Repr::F64(_) => "f64",
            Repr::I32(_) => "i32",
            Repr::I64(_) => "i64",
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn to_repr(data: Vec<Self>) -> Repr;
    #[doc(hidden)]
    fn from_repr(repr: &Repr) -> Option<Vec<Self>>;
    #[doc(hidden)]
    fn type_name() -> &'static str;
}

macro_rules! native_type {
    ($ty:ty, $variant:ident, $name:literal) => {
        impl NativeType for $ty {
            fn to_repr(data: Vec<Self>) -> Repr {
                Repr::$variant(data)
            }
            fn from_repr(repr: &Repr) -> Option<Vec<Self>> {
                match repr {
                    Repr::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
            fn type_name() -> &'static str {
                $name
            }
        }
    };
}

native_type!(f32, F32, "f32");
native_type!(f64, F64, "f64");
native_type!(i32, I32, "i32");
native_type!(i64, I64, "i64");

/// A typed host tensor: element data plus dimensions.
#[derive(Clone, Debug)]
pub struct Literal {
    repr: Repr,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            repr: T::to_repr(data.to_vec()),
            dims: vec![data.len() as i64],
        }
    }

    pub fn element_count(&self) -> usize {
        self.repr.len()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Same data, new shape; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.repr.len() {
            return Err(Error::new(format!(
                "reshape {:?} -> {:?}: element count mismatch ({} elements)",
                self.dims,
                dims,
                self.repr.len()
            )));
        }
        Ok(Literal { repr: self.repr.clone(), dims: dims.to_vec() })
    }

    /// Copy the elements out, checking the requested type.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_repr(&self.repr).ok_or_else(|| {
            Error::new(format!(
                "literal holds {}, requested {}",
                self.repr.type_name(),
                T::type_name()
            ))
        })
    }

    /// Decompose a tuple literal. Stub literals are never tuples.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::new("literal is not a tuple (vendor/xla stub)"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module. The stub cannot parse HLO text.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parse HLO text {path}")))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments; `[replica][output]` buffers.
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client. Construction fails in the stub: there is no runtime.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.element_count(), 6);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.dims(), &[2, 3]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
        assert!(m.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not linked"));
    }
}
