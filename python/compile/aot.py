"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Artifacts produced (shapes baked per variant):
  forest_b{B}.hlo.txt         for B in FOREST_BATCH_SIZES
  stencil_{pattern}_r{R}.hlo.txt  for the three Fig.-5 patterns
  manifest.json               shape/contract description for the rust side
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import (FOREST_BATCH_SIZES, MAX_DEPTH, MAX_NODES, NUM_FEATURES,
                     NUM_TREES, STENCIL_EPILOGUE, STENCIL_IMG,
                     STENCIL_PATTERNS, STENCIL_RADIUS, STENCIL_TILE,
                     stencil_offsets)
from .model import forest_model, make_stencil_model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forest(batch: int) -> str:
    f32 = jnp.float32
    i32 = jnp.int32
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(forest_model).lower(
        spec((batch, NUM_FEATURES), f32),
        spec((NUM_TREES, MAX_NODES), i32),
        spec((NUM_TREES, MAX_NODES), f32),
        spec((NUM_TREES, MAX_NODES), i32),
        spec((NUM_TREES, MAX_NODES), i32),
        spec((NUM_TREES, MAX_NODES), f32),
    )
    return to_hlo_text(lowered)


def lower_stencil(pattern: str) -> str:
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    r = STENCIL_RADIUS
    k = len(stencil_offsets(pattern, r))
    model = make_stencil_model(pattern, r, STENCIL_TILE, STENCIL_EPILOGUE)
    lowered = jax.jit(model).lower(
        spec((STENCIL_IMG + 2 * r, STENCIL_IMG + 2 * r), f32),
        spec((k,), f32),
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--forest-only", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "num_trees": NUM_TREES,
        "max_nodes": MAX_NODES,
        "num_features": NUM_FEATURES,
        "max_depth": MAX_DEPTH,
        "forest_batch_sizes": list(FOREST_BATCH_SIZES),
        "stencil": {
            "img": STENCIL_IMG,
            "tile": STENCIL_TILE,
            "radius": STENCIL_RADIUS,
            "epilogue": STENCIL_EPILOGUE,
            "patterns": {
                p: len(stencil_offsets(p, STENCIL_RADIUS))
                for p in STENCIL_PATTERNS
            },
        },
        "artifacts": [],
    }

    for b in FOREST_BATCH_SIZES:
        name = f"forest_b{b}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_forest(b)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(name)
        print(f"wrote {name} ({len(text)} chars)")

    if not args.forest_only:
        for p in STENCIL_PATTERNS:
            name = f"stencil_{p}_r{STENCIL_RADIUS}.hlo.txt"
            path = os.path.join(args.out_dir, name)
            text = lower_stencil(p)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(name)
            print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
