"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Everything here is deliberately written in the most direct jnp style —
no tiling, no pallas — so pytest can assert_allclose the optimized
kernels against an independent formulation.
"""

import jax.numpy as jnp

from ..config import stencil_offsets


def forest_predict_ref(features, feat_idx, thresh, left, right, leaf, depth):
    """Reference batched random-forest regression inference.

    features : [B, F] f32
    feat_idx : [T, N] i32   feature tested at each node (leaves: 0)
    thresh   : [T, N] f32   split threshold (go left iff x[f] <= t)
    left     : [T, N] i32   left-child node id (leaves: self)
    right    : [T, N] i32   right-child node id (leaves: self)
    leaf     : [T, N] f32   prediction payload (internal nodes: 0)
    depth    : int          traversal iterations (>= max tree depth;
                            leaves self-loop so extra iterations are no-ops)

    Returns [B] f32 — mean over trees of the reached leaf value.
    """
    b = features.shape[0]
    t = feat_idx.shape[0]
    rows = jnp.arange(b)
    total = jnp.zeros((b,), jnp.float32)
    for ti in range(t):
        nodes = jnp.zeros((b,), jnp.int32)
        for _ in range(depth):
            fi = jnp.take(feat_idx[ti], nodes)
            th = jnp.take(thresh[ti], nodes)
            fv = features[rows, fi]
            go_left = fv <= th
            nodes = jnp.where(go_left,
                              jnp.take(left[ti], nodes),
                              jnp.take(right[ti], nodes))
        total = total + jnp.take(leaf[ti], nodes)
    return total / jnp.float32(t)


def stencil_ref(inp, pattern, radius, weights, epilogue):
    """Reference synthetic-template work-unit compute (Fig. 3 of the paper).

    Each output element is the weighted sum of target-array taps around its
    home coordinate (the selected stencil pattern, Fig. 5), followed by an
    epilogue FMA chain. The input is assumed pre-padded by `radius` on each
    side: inp is [H + 2r, W + 2r], output is [H, W].
    """
    offs = stencil_offsets(pattern, radius)
    assert len(weights) == len(offs)
    h = inp.shape[0] - 2 * radius
    w = inp.shape[1] - 2 * radius
    acc = jnp.zeros((h, w), jnp.float32)
    for wk, (dy, dx) in zip(weights, offs):
        acc = acc + jnp.float32(wk) * inp[radius + dy: radius + dy + h,
                                          radius + dx: radius + dx + w]
    for _ in range(epilogue):
        acc = acc * jnp.float32(1.0009765625) + jnp.float32(0.03125)
    return acc
