"""L1 Pallas kernel: the synthetic-template work-unit compute (paper Fig. 3).

This is the *optimized* variant of the paper's kernel template: the region
of the target array `in` that a workgroup's work-units touch — the grey
region of Fig. 4 extended by the stencil apron (Fig. 5) — is staged into
on-chip memory once, and all taps read from the staged tile.

Hardware adaptation (GPU shared memory -> TPU VMEM): the paper's
workgroup-cooperative coalesced copy becomes an explicit `pl.load` of the
apron-extended tile from the unblocked input ref — Pallas stages it
HBM->VMEM; the (2r+1)^2 taps then hit VMEM only, the exact analog of the
shared-memory reads in the paper's optimized OpenCL kernel. The epilogue FMA
chain models the template's contextual computation (NUM_COMP_EP).

interpret=True: see kernels/forest.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import stencil_offsets


def _stencil_kernel(in_ref, w_ref, o_ref, *, offsets, radius, tile, epilogue):
    iy = pl.program_id(0)
    ix = pl.program_id(1)
    r = radius

    # The cooperative load (paper Fig. 3 line 18-19): one apron-extended
    # tile of the padded input staged into on-chip memory.
    y0 = iy * tile
    x0 = ix * tile
    staged = in_ref[pl.dslice(y0, tile + 2 * r),
                    pl.dslice(x0, tile + 2 * r)]
    weights = w_ref[...]

    acc = jnp.zeros((tile, tile), jnp.float32)
    for k, (dy, dx) in enumerate(offsets):
        tap = jax.lax.dynamic_slice(staged, (r + dy, r + dx), (tile, tile))
        acc = acc + weights[k] * tap

    # Epilogue context (template lines 32-33): a short FMA chain.
    for _ in range(epilogue):
        acc = acc * jnp.float32(1.0009765625) + jnp.float32(0.03125)

    o_ref[...] = acc


def stencil_apply(inp, weights, *, pattern, radius, tile, epilogue):
    """Run the template work-unit compute over a padded input.

    inp     : [H + 2r, W + 2r] f32 (pre-padded target array; the paper pads
              `in` to avoid out-of-bounds accesses)
    weights : [K] f32, one per stencil tap (K = len(stencil_offsets))
    Returns [H, W] f32.
    """
    offsets = stencil_offsets(pattern, radius)
    hp, wp = inp.shape
    h, w = hp - 2 * radius, wp - 2 * radius
    assert h % tile == 0 and w % tile == 0, (h, w, tile)
    assert weights.shape == (len(offsets),)

    kernel = functools.partial(_stencil_kernel, offsets=offsets,
                               radius=radius, tile=tile, epilogue=epilogue)
    grid = (h // tile, w // tile)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),     # unblocked: kernel stages
            pl.BlockSpec((len(offsets),), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(inp, weights)
