"""L1 Pallas kernel: batched random-forest regression inference.

The serving hot path of the auto-tuner (paper Fig. 2, right side): given a
batch of 18-dim feature vectors and the tensor-encoded forest, walk every
tree and average the reached leaf values.

Tensor encoding (produced by rust/src/ml/export.rs):
  feat_idx [T, N] i32, thresh [T, N] f32, left/right [T, N] i32,
  leaf [T, N] f32.  Leaves self-loop (left == right == self), so running
  the traversal for a fixed DEPTH >= max tree depth is exact.

Kernel layout: grid = (batch_tiles, trees). Each grid step loads one tree's
node tables (a [1, N] block per table — the VMEM-resident "local memory" of
this kernel) plus one [BT, F] feature tile, performs DEPTH gather steps, and
accumulates leaf values into the output tile. Tree 0 initializes the
accumulator; the final tree divides by T.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that both pytest and the
rust runtime can run. On a real TPU the same BlockSpec schedule stages each
tree's tables HBM->VMEM exactly once per batch tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import MAX_DEPTH, NUM_TREES


def _forest_kernel(f_ref, fi_ref, th_ref, lt_ref, rt_ref, lf_ref, o_ref,
                   *, depth, num_trees):
    t = pl.program_id(1)

    feats = f_ref[...]                 # [BT, F]
    fidx = fi_ref[0, :]                # [N]
    thr = th_ref[0, :]
    lft = lt_ref[0, :]
    rgt = rt_ref[0, :]
    leaf = lf_ref[0, :]

    bt = feats.shape[0]
    rows = jax.lax.iota(jnp.int32, bt)

    def step(_, nodes):
        fi = jnp.take(fidx, nodes)
        th = jnp.take(thr, nodes)
        fv = feats[rows, fi]
        return jnp.where(fv <= th, jnp.take(lft, nodes), jnp.take(rgt, nodes))

    nodes0 = jnp.zeros((bt,), jnp.int32)
    nodes = jax.lax.fori_loop(0, depth, step, nodes0)
    vals = jnp.take(leaf, nodes)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += vals

    @pl.when(t == num_trees - 1)
    def _finish():
        o_ref[...] = o_ref[...] / jnp.float32(num_trees)


def forest_predict(features, feat_idx, thresh, left, right, leaf,
                   *, batch_tile=64, depth=MAX_DEPTH):
    """Batched forest inference. features [B, F] -> predictions [B].

    B must be a multiple of batch_tile (the rust router pads).
    """
    b, f = features.shape
    t, n = feat_idx.shape
    assert b % batch_tile == 0, (b, batch_tile)

    grid = (b // batch_tile, t)
    kernel = functools.partial(_forest_kernel, depth=depth, num_trees=t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch_tile, f), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((batch_tile,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(features, feat_idx, thresh, left, right, leaf)
