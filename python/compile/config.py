"""Shared compile-time constants for the L1/L2 <-> L3 contract.

These sizes are baked into the AOT artifacts; the rust side
(rust/src/runtime/forest_exec.rs, rust/src/ml/export.rs) must agree.
Keep in sync with `rust/src/runtime/contract.rs`.
"""

# ---- Random-forest tensor encoding ------------------------------------
NUM_TREES = 20          # paper: Weka RF with 20 trees
MAX_NODES = 8192        # per-tree node-table padding (leaves self-loop)
NUM_FEATURES = 18       # paper section 4.2: 18 model inputs
MAX_DEPTH = 32          # traversal iterations; >= exported tree depth
# Batch-size variants compiled AOT; the rust router pads to the smallest fit.
FOREST_BATCH_SIZES = (64, 256, 1024, 4096)

# ---- Synthetic-template stencil executor -------------------------------
STENCIL_PATTERNS = ("rect", "diamond", "star")   # paper figure 5
STENCIL_IMG = 256        # H == W of the target array for the executor
STENCIL_TILE = 32        # output tile (the "workgroup" analog)
STENCIL_RADIUS = 1       # radius baked into the default artifacts
STENCIL_EPILOGUE = 4     # epilogue FMA chain length


def stencil_offsets(pattern: str, radius: int):
    """Tap offsets (dy, dx) for the paper's three stencil shapes (Fig. 5).

    rect    : full (2r+1)^2 square
    diamond : |dy| + |dx| <= r
    star    : taps on the two axes only
    Mirrors rust/src/kernelmodel/stencil.rs exactly.
    """
    if radius == 0:
        return [(0, 0)]
    offs = []
    r = radius
    for dy in range(-r, r + 1):
        for dx in range(-r, r + 1):
            if pattern == "rect":
                offs.append((dy, dx))
            elif pattern == "diamond":
                if abs(dy) + abs(dx) <= r:
                    offs.append((dy, dx))
            elif pattern == "star":
                if dy == 0 or dx == 0:
                    offs.append((dy, dx))
            else:
                raise ValueError(f"unknown stencil pattern {pattern!r}")
    return offs
