"""L2: the jax compute graphs that the rust coordinator executes via PJRT.

Two graphs, both calling the L1 Pallas kernels:

  * ``forest_model`` — the serving hot path: batched RF inference over the
    tensor-encoded forest (kernels/forest.py).
  * ``stencil_model`` — the synthetic-template executor: runs the paper's
    work-unit compute over a target array (kernels/stencil.py), used by the
    stencil_pipeline example to demonstrate that template instances are
    real computations, not just simulator descriptors.

Both are pure functions of their inputs so AOT lowering needs no closure
state; all shapes are static per artifact variant (see aot.py).
"""

import jax.numpy as jnp

from .config import MAX_DEPTH
from .kernels.forest import forest_predict
from .kernels.stencil import stencil_apply


def forest_model(features, feat_idx, thresh, left, right, leaf):
    """features [B,18] + forest tensors -> (predictions [B],).

    The prediction is the forest-mean regression output; the rust side
    interprets it as log2(speedup): > 0 means "apply the optimization".
    """
    # Perf (EXPERIMENTS.md §Perf L1): one full-batch tile instead of
    # 64-row tiles — fewer pipeline steps, wider vector ops; 13x faster
    # under interpret mode and a single HBM->VMEM stage per tree on TPU
    # (B=4096 x 18 f32 = 288 KB tile + 5 x 32 KB node tables << VMEM).
    preds = forest_predict(features, feat_idx, thresh, left, right, leaf,
                           batch_tile=features.shape[0],
                           depth=MAX_DEPTH)
    return (preds,)


def make_stencil_model(pattern, radius, tile, epilogue):
    """Build the stencil executor for one (pattern, radius) artifact."""

    def stencil_model(inp, weights):
        out = stencil_apply(inp, weights, pattern=pattern, radius=radius,
                            tile=tile, epilogue=epilogue)
        # Checksum lets the rust side sanity-check numerics cheaply without
        # pulling the whole output back for large arrays.
        return (out, jnp.sum(out, dtype=jnp.float32))

    return stencil_model
