"""Pallas stencil kernel vs pure-jnp oracle + template-semantics checks."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import stencil_offsets
from compile.kernels.ref import stencil_ref
from compile.kernels.stencil import stencil_apply


def _pad(img, r):
    return np.pad(img, r, mode="constant") if r else img


def _run_both(rng, h, w, pattern, radius, tile, epilogue):
    offs = stencil_offsets(pattern, radius)
    img = rng.standard_normal((h, w)).astype(np.float32)
    weights = rng.standard_normal(len(offs)).astype(np.float32)
    padded = _pad(img, radius)
    got = stencil_apply(padded, weights, pattern=pattern, radius=radius,
                        tile=tile, epilogue=epilogue)
    want = stencil_ref(padded, pattern, radius, weights, epilogue)
    return np.asarray(got), np.asarray(want)


@pytest.mark.parametrize("pattern", ["rect", "diamond", "star"])
def test_stencil_matches_ref(pattern, rng):
    got, want = _run_both(rng, 64, 64, pattern, radius=1, tile=16,
                          epilogue=4)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("radius", [0, 1, 2])
def test_stencil_radii(radius, rng):
    got, want = _run_both(rng, 32, 32, "rect", radius=radius, tile=16,
                          epilogue=0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_identity_stencil(rng):
    # radius 0 single tap with weight 1 and no epilogue == identity.
    img = rng.standard_normal((32, 32)).astype(np.float32)
    got = np.asarray(stencil_apply(img, np.ones(1, np.float32),
                                   pattern="rect", radius=0, tile=16,
                                   epilogue=0))
    np.testing.assert_allclose(got, img)


def test_offsets_counts():
    # Fig. 5 tap counts: rect (2r+1)^2, diamond 2r^2+2r+1, star 4r+1.
    for r in range(0, 4):
        assert len(stencil_offsets("rect", r)) == (2 * r + 1) ** 2
        assert len(stencil_offsets("diamond", r)) == 2 * r * r + 2 * r + 1
        assert len(stencil_offsets("star", r)) == (4 * r + 1 if r else 1)


def test_star_subset_of_diamond_subset_of_rect():
    for r in (1, 2, 3):
        rect = set(stencil_offsets("rect", r))
        dia = set(stencil_offsets("diamond", r))
        star = set(stencil_offsets("star", r))
        assert star <= dia <= rect
        assert (0, 0) in star


def test_constant_input_rect(rng):
    # Constant input: every output equals sum(w) * c through the epilogue.
    c = 2.5
    r, tile, ep = 1, 16, 3
    offs = stencil_offsets("rect", r)
    w = rng.standard_normal(len(offs)).astype(np.float32)
    img = np.full((32 + 2 * r, 32 + 2 * r), c, np.float32)
    got = np.asarray(stencil_apply(img, w, pattern="rect", radius=r,
                                   tile=tile, epilogue=ep))
    val = np.float32(w.sum() * c)
    for _ in range(ep):
        val = val * np.float32(1.0009765625) + np.float32(0.03125)
    np.testing.assert_allclose(got, np.full((32, 32), val), rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(pattern=st.sampled_from(["rect", "diamond", "star"]),
       radius=st.integers(0, 2),
       tiles=st.integers(1, 3),
       epilogue=st.integers(0, 6),
       seed=st.integers(0, 2**31 - 1))
def test_stencil_matches_ref_property(pattern, radius, tiles, epilogue,
                                      seed):
    rng = np.random.default_rng(seed)
    hw = 16 * tiles
    got, want = _run_both(rng, hw, hw, pattern, radius, tile=16,
                          epilogue=epilogue)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_tile_invariance(rng):
    got16, _ = _run_both(np.random.default_rng(7), 64, 64, "diamond", 1,
                         tile=16, epilogue=2)
    got32, _ = _run_both(np.random.default_rng(7), 64, 64, "diamond", 1,
                         tile=32, epilogue=2)
    np.testing.assert_allclose(got16, got32, rtol=1e-6, atol=1e-6)
