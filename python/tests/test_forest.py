"""Pallas forest kernel vs pure-jnp oracle, plus oracle self-checks."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import MAX_DEPTH, NUM_FEATURES
from compile.kernels.forest import forest_predict
from compile.kernels.ref import forest_predict_ref
from tests.conftest import make_random_forest


def _run_both(rng, batch, trees, nodes, depth_grow, batch_tile):
    fi, th, lt, rt, lf = make_random_forest(
        rng, trees, nodes, NUM_FEATURES, max_depth=depth_grow)
    feats = rng.standard_normal((batch, NUM_FEATURES)).astype(np.float32)
    got = forest_predict(feats, fi, th, lt, rt, lf,
                         batch_tile=batch_tile, depth=MAX_DEPTH)
    want = forest_predict_ref(feats, fi, th, lt, rt, lf, MAX_DEPTH)
    return np.asarray(got), np.asarray(want)


def test_forest_matches_ref_small(rng):
    got, want = _run_both(rng, batch=64, trees=4, nodes=64,
                          depth_grow=5, batch_tile=32)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_forest_matches_ref_full_contract(rng):
    # full contract sizes (T=20 is what the artifacts bake)
    got, want = _run_both(rng, batch=128, trees=20, nodes=256,
                          depth_grow=7, batch_tile=64)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_single_node_trees_predict_their_leaf(rng):
    # Trees that are a single leaf: prediction == mean of the leaf values.
    fi, th, lt, rt, lf = make_random_forest(rng, 5, 8, NUM_FEATURES,
                                            max_depth=0)
    feats = rng.standard_normal((64, NUM_FEATURES)).astype(np.float32)
    got = np.asarray(forest_predict(feats, fi, th, lt, rt, lf,
                                    batch_tile=64, depth=MAX_DEPTH))
    want = np.full(64, lf[:, 0].mean(), np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_stump_decision_boundary(rng):
    # One tree, one split on feature 3 at 0.0: left leaf -1, right leaf +1.
    n = 8
    fi = np.zeros((1, n), np.int32)
    th = np.zeros((1, n), np.float32)
    lt = np.tile(np.arange(n, dtype=np.int32), (1, 1))
    rt = lt.copy()
    lf = np.zeros((1, n), np.float32)
    fi[0, 0] = 3
    lt[0, 0], rt[0, 0] = 1, 2
    lf[0, 1], lf[0, 2] = -1.0, 1.0
    feats = np.zeros((64, NUM_FEATURES), np.float32)
    feats[:, 3] = np.linspace(-2, 2, 64)
    got = np.asarray(forest_predict(feats, fi, th, lt, rt, lf,
                                    batch_tile=64, depth=MAX_DEPTH))
    want = np.where(feats[:, 3] <= 0.0, -1.0, 1.0).astype(np.float32)
    np.testing.assert_allclose(got, want)


def test_extra_depth_is_noop(rng):
    # Leaves self-loop: traversing deeper than the tree changes nothing.
    fi, th, lt, rt, lf = make_random_forest(rng, 3, 64, NUM_FEATURES,
                                            max_depth=4)
    feats = rng.standard_normal((32, NUM_FEATURES)).astype(np.float32)
    a = np.asarray(forest_predict_ref(feats, fi, th, lt, rt, lf, 6))
    b = np.asarray(forest_predict_ref(feats, fi, th, lt, rt, lf, 30))
    np.testing.assert_allclose(a, b)


@settings(max_examples=15, deadline=None)
@given(batch_tiles=st.integers(1, 4),
       trees=st.integers(1, 8),
       nodes=st.sampled_from([16, 64, 128]),
       depth_grow=st.integers(0, 6),
       seed=st.integers(0, 2**31 - 1))
def test_forest_matches_ref_property(batch_tiles, trees, nodes,
                                     depth_grow, seed):
    rng = np.random.default_rng(seed)
    got, want = _run_both(rng, batch=32 * batch_tiles, trees=trees,
                          nodes=nodes, depth_grow=depth_grow, batch_tile=32)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_batch_tile_invariance(rng):
    # Same inputs, different tilings -> identical outputs.
    fi, th, lt, rt, lf = make_random_forest(rng, 6, 128, NUM_FEATURES,
                                            max_depth=6)
    feats = rng.standard_normal((128, NUM_FEATURES)).astype(np.float32)
    a = np.asarray(forest_predict(feats, fi, th, lt, rt, lf,
                                  batch_tile=32, depth=MAX_DEPTH))
    b = np.asarray(forest_predict(feats, fi, th, lt, rt, lf,
                                  batch_tile=128, depth=MAX_DEPTH))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
