"""Test helpers: random valid forest tensors + stencil inputs."""

import numpy as np
import pytest


def make_random_forest(rng, num_trees, max_nodes, num_features,
                       max_depth=8, p_leaf=0.3):
    """Build random *valid* tensor-encoded trees.

    Validity contract (mirrors rust/src/ml/export.rs):
      - node 0 is the root
      - children have larger indices than parents (no cycles)
      - leaves self-loop (left == right == self) and carry the payload
      - all nodes beyond the used range are self-looping leaves
    """
    t = num_trees
    n = max_nodes
    feat_idx = np.zeros((t, n), np.int32)
    thresh = np.zeros((t, n), np.float32)
    left = np.tile(np.arange(n, dtype=np.int32), (t, 1))
    right = left.copy()
    leaf = np.zeros((t, n), np.float32)

    for ti in range(t):
        # grow a random binary tree breadth-first
        next_free = [1]
        depth_of = {0: 0}
        frontier = [0]
        while frontier:
            node = frontier.pop()
            d = depth_of[node]
            is_leaf = (d >= max_depth or next_free[0] + 2 > n
                       or rng.random() < p_leaf)
            if is_leaf:
                leaf[ti, node] = rng.standard_normal()
            else:
                l, r = next_free[0], next_free[0] + 1
                next_free[0] += 2
                feat_idx[ti, node] = rng.integers(0, num_features)
                thresh[ti, node] = rng.standard_normal()
                left[ti, node] = l
                right[ti, node] = r
                depth_of[l] = depth_of[r] = d + 1
                frontier += [l, r]
    return feat_idx, thresh, left, right, leaf


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)
