//! Reproduce every table and figure of the paper in one run.
//!
//! Scaled by LMTUNER_SCALE (default 0.2 = 20 context tuples; 1.0 = the
//! paper's 100 tuples). Output is the per-figure index that DESIGN.md §5
//! and EXPERIMENTS.md reference.
//!
//! Run: cargo run --release --offline --example reproduce_paper

use lmtuner::coordinator::train::{self, TrainConfig};
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::report::{figures, tables};

fn main() {
    let scale: f64 = std::env::var("LMTUNER_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);
    let dev = DeviceSpec::m2090();
    let cfg = TrainConfig { scale, configs_per_kernel: 32, ..Default::default() };

    println!("{}", tables::table1());
    println!("{}", tables::table2(cfg.seed, 100_000));
    println!("{}", tables::table3(&dev));

    eprintln!("building dataset + training (scale {scale}) ...");
    let out = train::run(&dev, &cfg);
    let real = figures::real_benchmark_records(&dev, &cfg.measure);

    println!("{}", figures::fig1(&out.records, &real));
    println!("{}", figures::fig6(&out.synth_accuracy, &out.per_benchmark));

    println!("=== paper-vs-measured summary ===");
    println!(
        "synthetic count-based accuracy   : paper ~86%   ours {:.1}%",
        100.0 * out.synth_accuracy.count_based
    );
    println!(
        "synthetic penalty-weighted       : paper ~95%   ours {:.1}%",
        100.0 * out.synth_accuracy.penalty_weighted
    );
    let avg = out
        .per_benchmark
        .iter()
        .map(|(_, a)| a.penalty_weighted)
        .sum::<f64>()
        / out.per_benchmark.len() as f64;
    println!("real penalty-weighted (average)  : paper ~95%   ours {:.1}%", 100.0 * avg);
    let min_speedup = out
        .records
        .iter()
        .map(|r| r.speedup)
        .fold(f64::INFINITY, f64::min);
    let max_speedup = out.records.iter().map(|r| r.speedup).fold(0.0, f64::max);
    println!(
        "synthetic speedup range          : paper 0.03x-49.6x   ours {min_speedup:.2}x-{max_speedup:.1}x"
    );
}
