//! Run the synthetic-template stencil compute through the full
//! three-layer stack: the L1 Pallas kernel (VMEM-staged taps — the TPU
//! analog of the paper's local-memory staging) was lowered via the L2
//! jax graph to HLO text at build time; here the L3 rust side loads it
//! with PJRT, feeds a real image-like input, and cross-checks numerics
//! against an independent rust oracle.
//!
//! Run: make artifacts && cargo run --release --offline --example stencil_pipeline

use lmtuner::kernelmodel::stencil::StencilPattern;
use lmtuner::runtime::pjrt::Engine;
use lmtuner::runtime::stencil_exec::StencilExecutor;
use lmtuner::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(std::path::Path::new("artifacts"))?;
    let exec = StencilExecutor::new(&engine)?;
    println!(
        "stencil executor: {}x{} image, radius {}, platform {}",
        exec.img,
        exec.img,
        exec.radius,
        engine.platform()
    );

    let side = exec.img + 2 * exec.radius;
    let mut rng = Rng::new(0xBEEF);
    // A smooth synthetic "image": low-frequency bumps + noise.
    let padded: Vec<f32> = (0..side * side)
        .map(|i| {
            let y = (i / side) as f32 / side as f32;
            let x = (i % side) as f32 / side as f32;
            (6.3 * x).sin() * (6.3 * y).cos() + 0.05 * (rng.next_f32() - 0.5)
        })
        .collect();

    for pattern in StencilPattern::ALL {
        let taps = exec.taps(pattern);
        // Normalized blur weights.
        let weights: Vec<f32> = vec![1.0 / taps as f32; taps];
        let t0 = std::time::Instant::now();
        let run = exec.run(pattern, &padded, &weights)?;
        let dt = t0.elapsed();
        let want = exec.reference(pattern, &padded, &weights);
        let max_err = run
            .output
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        println!(
            "{pattern:<8} taps={taps:<2} pjrt {dt:>10?}  checksum {:>12.4}  max|err| vs rust oracle {max_err:.2e}  {}",
            run.checksum,
            if max_err < 1e-3 { "OK" } else { "MISMATCH" }
        );
        assert!(max_err < 1e-3);
    }
    println!("all three Fig.-5 stencil patterns verified through the PJRT path");
    Ok(())
}
