//! End-to-end serving driver — the repository's full-stack validation.
//!
//! Exercises every layer together: trains the forest (L3), exports it to
//! the tensor contract, starts the sharded batched prediction service,
//! and replays the complete real-benchmark instance stream (all Table-3
//! instances, repeated) as concurrent requests. When AOT artifacts are
//! present the batches run through the PJRT executable (the L2 jax graph
//! wrapping the L1 Pallas forest kernel); without them the service uses
//! the native batched executor, so this driver needs no `make artifacts`.
//!
//! Reports decision accuracy against the oracle plus latency/throughput
//! percentiles. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: cargo run --release --offline --example autotune_service

use std::sync::Arc;
use std::time::Instant;

use lmtuner::coordinator::service::{Service, ServiceConfig};
use lmtuner::coordinator::train::{self, TrainConfig};
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::ml::metrics;
use lmtuner::runtime::pjrt::Engine;
use lmtuner::sim::exec::{measure, MeasureConfig, SpeedupRecord};
use lmtuner::util::stats::percentile;
use lmtuner::workloads;

const REPEATS: usize = 8;

fn main() -> anyhow::Result<()> {
    let dev = DeviceSpec::m2090();

    // --- Phase 1: train (L3 native) --------------------------------
    let cfg = TrainConfig { scale: 0.2, configs_per_kernel: 24, ..Default::default() };
    println!("[1/4] training forest (scale {}) ...", cfg.scale);
    let out = train::run(&dev, &cfg);
    println!(
        "      {} instances, synth accuracy: count {:.1}% / penalty {:.1}%",
        out.records.len(),
        100.0 * out.synth_accuracy.count_based,
        100.0 * out.synth_accuracy.penalty_weighted
    );

    // --- Pick a backend + start the service -------------------------
    println!("[2/4] selecting inference backend ...");
    let svc_cfg = ServiceConfig {
        max_batch: 1024,
        max_wait: std::time::Duration::from_micros(200),
        workers: 2,
        ..Default::default()
    };
    println!("[3/4] starting batched prediction service ...");
    let svc = match Engine::new(std::path::Path::new("artifacts")) {
        Ok(engine) => {
            let engine = Arc::new(engine);
            let n = engine.warmup()?;
            println!("      compiled {n} artifacts on {}", engine.platform());
            let encoded = train::encode_for_serving(&out.forest, &engine.manifest);
            println!(
                "      forest encoded: {} truncated splits (budget {} nodes x {} trees)",
                encoded.truncated, engine.manifest.max_nodes, engine.manifest.num_trees
            );
            Service::start_pjrt(engine, encoded, svc_cfg)?
        }
        Err(e) => {
            println!("      artifacts unavailable ({e:#})");
            println!("      using the native batched executor (no artifacts needed)");
            Service::start_native(train::encode_default(&out.forest), svc_cfg)?
        }
    };
    let handle = svc.handle();

    // --- Replay the real-benchmark stream ---------------------------
    let mut oracle: Vec<SpeedupRecord> = Vec::new();
    let mcfg = MeasureConfig::default();
    for b in workloads::all() {
        for d in (b.instances)(&dev) {
            oracle.push(measure(&d, &dev, &mcfg));
        }
    }
    let total = oracle.len() * REPEATS;
    println!(
        "[4/4] replaying {total} requests ({} unique instances x {REPEATS}) ...",
        oracle.len()
    );

    let t0 = Instant::now();
    let (tx, rx) = std::sync::mpsc::channel();
    let mut clients = Vec::new();
    let handle2 = handle.clone();
    let oracle2: Arc<Vec<SpeedupRecord>> = Arc::new(oracle);
    for c in 0..4 {
        let h = handle2.clone();
        let tx = tx.clone();
        let orc = oracle2.clone();
        clients.push(std::thread::spawn(move || {
            let per = REPEATS / 4;
            for rep in 0..per {
                for (i, r) in orc.iter().enumerate() {
                    let id = ((c * per + rep) * orc.len() + i) as u64;
                    while h.submit(id, r.features, tx.clone()).is_err() {
                        std::thread::yield_now(); // backpressure
                    }
                }
            }
        }));
    }
    drop(tx);

    let mut lat_us = Vec::with_capacity(total);
    let mut decisions: Vec<(u64, bool)> = Vec::with_capacity(total);
    let mut batch_sizes = Vec::new();
    for _ in 0..total {
        let resp = rx.recv()??; // channel error, then typed batch error
        lat_us.push(resp.latency.as_secs_f64() * 1e6);
        decisions.push((resp.id, resp.use_local_memory));
        batch_sizes.push(resp.batch_size as f64);
    }
    let elapsed = t0.elapsed();
    for c in clients {
        c.join().unwrap();
    }
    drop(handle);
    drop(handle2);
    let stats = svc.shutdown();

    // --- Grade decisions against the oracle -------------------------
    let orc = &*oracle2;
    let graded: Vec<bool> = decisions
        .iter()
        .map(|(id, d)| {
            let r = &orc[*id as usize % orc.len()];
            *d == r.beneficial()
        })
        .collect();
    let refs: Vec<&SpeedupRecord> = decisions
        .iter()
        .map(|(id, _)| &orc[*id as usize % orc.len()])
        .collect();
    let dec_only: Vec<bool> = decisions.iter().map(|(_, d)| *d).collect();
    let acc = metrics::evaluate(&refs, &dec_only);

    println!("\n=== end-to-end results ===");
    println!(
        "throughput : {:.0} decisions/s ({} served, {} batches, mean batch {:.0})",
        stats.served as f64 / elapsed.as_secs_f64(),
        stats.served,
        stats.batches,
        batch_sizes.iter().sum::<f64>() / batch_sizes.len().max(1) as f64
    );
    println!(
        "latency    : p50 {:.0}us  p95 {:.0}us  p99 {:.0}us  max {:.0}us",
        percentile(&lat_us, 50.0),
        percentile(&lat_us, 95.0),
        percentile(&lat_us, 99.0),
        percentile(&lat_us, 100.0)
    );
    println!(
        "accuracy   : count {:.1}%  penalty-weighted {:.1}%  ({} correct / {})",
        100.0 * acc.count_based,
        100.0 * acc.penalty_weighted,
        graded.iter().filter(|&&g| g).count(),
        graded.len()
    );
    Ok(())
}
