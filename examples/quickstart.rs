//! Quickstart: the whole framework in ~60 lines.
//!
//! 1. Generate a small synthetic kernel population (paper §4.1).
//! 2. "Measure" each instance with and without the local-memory
//!    optimization on the simulated M2090.
//! 3. Train the Random Forest on 10% (paper §5.1).
//! 4. Evaluate both accuracy metrics on the held-out 90%.
//! 5. Ask the model about one concrete kernel.
//!
//! Run: cargo run --release --offline --example quickstart

use lmtuner::coordinator::train::{self, TrainConfig};
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::kernelmodel::access::HomePattern;
use lmtuner::kernelmodel::features;
use lmtuner::kernelmodel::launch::{GridGeom, Launch, WgGeom};
use lmtuner::kernelmodel::template::Template;
use lmtuner::report::figures;

fn main() {
    let dev = DeviceSpec::m2090();

    // Phase 1: a scaled-down pipeline (5 context tuples -> ~560 kernels).
    let cfg = TrainConfig {
        scale: 0.05,
        configs_per_kernel: 12,
        ..TrainConfig::default()
    };
    println!("running phase-1 pipeline (scale {}) ...", cfg.scale);
    let out = train::run(&dev, &cfg);
    println!(
        "  {} kernel instances simulated in {:.1}s, trained on {} in {:.1}s\n",
        out.records.len(),
        out.gen_seconds,
        out.train_size,
        out.fit_seconds
    );
    println!("{}", figures::fig6(&out.synth_accuracy, &out.per_benchmark));

    // Phase 2: query the model about a fresh kernel — a row-wise
    // reduction whose warp accesses are fully scattered (the paper's §2
    // motivating case). The oracle says "stage it"; the model should too.
    let t = Template {
        home: HomePattern::NoReuseRow,
        n: 1,
        m: 8,
        ..Template::base()
    };
    let launch = Launch::new(
        WgGeom { w: 32, h: 2 },
        GridGeom { w: 1024, h: 1024 },
    );
    let d = t.descriptor(&launch, &dev);
    let feats = features::extract(&d);
    let score = out.forest.predict(&feats);
    let oracle = lmtuner::sim::exec::measure(
        &d,
        &dev,
        &lmtuner::sim::exec::MeasureConfig::deterministic(),
    );
    println!(
        "query: {}\n  model:  log2(speedup) = {score:+.2} -> {}\n  oracle: speedup = {:.2}x -> {}",
        d.name,
        if score > 0.0 { "USE local memory" } else { "do NOT use" },
        oracle.speedup,
        if oracle.beneficial() { "USE local memory" } else { "do NOT use" },
    );
}
